//! Cross-module property tests (randomized invariant checks over the
//! coordinator's routing / batching / state management — the offline
//! substitute for proptest, see util::prop).

use cannikin::api::{self, BuildOptions, RunReport, SystemRegistry, TrainingSystem as _};
use cannikin::baselines::even_split;
use cannikin::cluster::{random_cluster, DeviceProfile};
use cannikin::elastic::{
    CheckpointPolicy, ChurnTrace, ClusterEvent, ElasticCluster, ReplanTiming, ScenarioConfig,
    TimedEvent,
};
use cannikin::gns;
use cannikin::obs::{tools, Tracer};
use cannikin::optperf::{self, Allocation, SolveCache, SolverWorkspace};
use cannikin::perfmodel::ClusterModel;
use cannikin::simulator::{workload, ClusterSim};
use cannikin::util::json::Json;
use cannikin::util::prop::{check, close, ensure};
use cannikin::util::rng::Rng;

fn random_model(rng: &mut Rng) -> ClusterModel {
    let n = 2 + rng.below(15) as usize;
    let cluster = random_cluster(rng, n);
    let ws = workload::all();
    let w = &ws[rng.below(ws.len() as u64) as usize];
    w.cluster_model(&cluster)
}

#[test]
fn prop_optperf_allocation_sums_to_total_and_is_nonnegative() {
    check(
        "optperf-sum",
        150,
        |rng| {
            let model = random_model(rng);
            let b = 8.0 + rng.f64() * 4000.0;
            (model, b)
        },
        |(model, b)| {
            let a = optperf::solve(model, *b).map_err(|e| e.to_string())?;
            let sum: f64 = a.batch_sizes.iter().sum();
            close(sum, *b, 1e-6, "sum(b) == B")?;
            ensure(a.batch_sizes.iter().all(|&x| x >= 0.0), "b >= 0")?;
            ensure(a.t_pred.is_finite() && a.t_pred > 0.0, "finite positive T")
        },
    );
}

#[test]
fn prop_optperf_never_worse_than_even_split() {
    check(
        "optperf-beats-even",
        100,
        |rng| {
            let model = random_model(rng);
            let b = 16.0 + rng.f64() * 2000.0;
            (model, b)
        },
        |(model, b)| {
            let a = optperf::solve(model, *b).map_err(|e| e.to_string())?;
            let even = vec![b / model.n() as f64; model.n()];
            let t_even = optperf::predict_batch_time(model, &even);
            ensure(
                a.t_pred <= t_even + 1e-9,
                format!("OptPerf {} > even {}", a.t_pred, t_even),
            )
        },
    );
}

#[test]
fn prop_algorithm1_agrees_with_water_filling() {
    check(
        "alg1-vs-bisection",
        100,
        |rng| {
            let model = random_model(rng);
            let b = 16.0 + rng.f64() * 3000.0;
            (model, b)
        },
        |(model, b)| {
            let a1 = optperf::solve(model, *b).map_err(|e| e.to_string())?;
            let a2 = optperf::solve_bisection(model, *b);
            close(a1.t_pred, a2.t_pred, 1e-4, "t_pred alg1 vs bisection")
        },
    );
}

#[test]
fn prop_algorithm1_matches_water_filling_at_scale() {
    // same agreement as above, but on clusters two orders of magnitude
    // larger than the planner ever sees — the packed workspace must not
    // change the answer at n where the old per-call-allocation solver was
    // too slow to property-test
    check(
        "alg1-vs-bisection-large",
        10,
        |rng| {
            let n = 64 + rng.below(449) as usize; // 64..=512
            let cluster = random_cluster(rng, n);
            let ws = workload::all();
            let w = &ws[rng.below(ws.len() as u64) as usize];
            let model = w.cluster_model(&cluster);
            // per-node averages from ~8 to ~128 samples keep all three
            // overlap regimes reachable across the corpus
            let b = n as f64 * (8.0 + rng.f64() * 120.0);
            (model, b)
        },
        |(model, b)| {
            let a1 = optperf::solve(model, *b).map_err(|e| e.to_string())?;
            let a2 = optperf::solve_bisection(model, *b);
            close(a1.t_pred, a2.t_pred, 1e-4, "t_pred alg1 vs bisection (large n)")
        },
    );
}

#[test]
fn prop_delta_solve_matches_cold_solve_after_node_removal() {
    // exact-sums delta path: build a candidate cache, remove a random
    // node with sum-patching against the old-bound workspace, and check
    // every delta answer against a cold solve of the shrunken model.
    // The shrunken model keeps gamma/t_comm fixed (pure membership
    // change), which is the contract under which exact patching is armed.
    check(
        "delta-vs-cold",
        30,
        |rng| {
            let n = 3 + rng.below(126) as usize; // 3..=128
            let cluster = random_cluster(rng, n);
            let ws = workload::all();
            let w = &ws[rng.below(ws.len() as u64) as usize];
            let model = w.cluster_model(&cluster);
            let victim = rng.below(n as u64) as usize;
            let base = (8 + rng.below(56)) * n as u64;
            let cands: Vec<u64> = (0..4).map(|i| base << i).collect();
            (model, victim, cands)
        },
        |(model, victim, cands)| {
            let mut ws = SolverWorkspace::new();
            let mut cache = SolveCache::new();
            let mut scratch = Allocation::empty();
            cache.rebuild(&mut ws, model, cands, &mut scratch);
            ensure(cache.is_exact(), "rebuild must arm the exact-sums path")?;

            let mut small = model.clone();
            small.nodes.remove(*victim);
            let old_ws = ws;
            let mut new_ws = SolverWorkspace::new();
            cache.delta_remove(*victim, Some(&old_ws));

            let mut hits = 0usize;
            for &b in cands.iter() {
                let mut out = Allocation::empty();
                let hit = cache
                    .delta_solve(&mut new_ws, &small, b, &mut out)
                    .map_err(|e| e.to_string())?;
                let cold = optperf::solve(&small, b as f64).map_err(|e| e.to_string())?;
                close(out.t_pred, cold.t_pred, 1e-9, "t_pred delta vs cold")?;
                ensure(
                    out.batch_sizes.len() == cold.batch_sizes.len(),
                    "allocation width",
                )?;
                for (x, y) in out.batch_sizes.iter().zip(&cold.batch_sizes) {
                    close(*x, *y, 1e-9, "per-node allocation delta vs cold")?;
                }
                if hit {
                    ensure(out.solves == 1, "fast path must be one linear solve")?;
                    hits += 1;
                }
            }
            // hits are state-dependent, not guaranteed per case — but the
            // fallback must still have produced cold-identical answers
            let _ = hits;
            Ok(())
        },
    );
}

#[test]
fn prop_delta_remove_with_t_comm_rescale_matches_cold() {
    // the planner's real removal sequence: sum-patch the victim out
    // against the old-bound workspace, then carry T_comm across the ring
    // resize analytically (2(n−1)/n) and patch the cached sums with
    // `rescale_t_comm` — the delta path must stay armed and agree with a
    // cold solve of the rescaled model to 1e-9.
    check(
        "delta-rescale-vs-cold",
        30,
        |rng| {
            let n = 3 + rng.below(62) as usize; // 3..=64
            let cluster = random_cluster(rng, n);
            let ws = workload::all();
            let w = &ws[rng.below(ws.len() as u64) as usize];
            let model = w.cluster_model(&cluster);
            let victim = rng.below(n as u64) as usize;
            let base = (8 + rng.below(56)) * n as u64;
            let cands: Vec<u64> = (0..4).map(|i| base << i).collect();
            (model, victim, cands)
        },
        |(model, victim, cands)| {
            let mut ws = SolverWorkspace::new();
            let mut cache = SolveCache::new();
            let mut scratch = Allocation::empty();
            cache.rebuild(&mut ws, model, cands, &mut scratch);
            ensure(cache.is_exact(), "rebuild must arm the exact-sums path")?;

            // the shrunken model after a ring resize: n → n−1 nodes and
            // T_comm scaled by ((n−2)/(n−1)) / ((n−1)/n)
            let n = model.n();
            let factor =
                ((n - 2) as f64 / (n - 1) as f64) / ((n - 1) as f64 / n as f64);
            let mut small = model.clone();
            small.nodes.remove(*victim);
            small.t_comm = model.t_comm * factor;

            let old_ws = ws;
            let mut new_ws = SolverWorkspace::new();
            cache.delta_remove(*victim, Some(&old_ws));
            cache.rescale_t_comm(model.t_o(), small.t_o());

            for &b in cands.iter() {
                let mut out = Allocation::empty();
                cache
                    .delta_solve(&mut new_ws, &small, b, &mut out)
                    .map_err(|e| e.to_string())?;
                let cold = optperf::solve(&small, b as f64).map_err(|e| e.to_string())?;
                close(out.t_pred, cold.t_pred, 1e-9, "t_pred delta+rescale vs cold")?;
                for (x, y) in out.batch_sizes.iter().zip(&cold.batch_sizes) {
                    close(*x, *y, 1e-9, "per-node allocation delta+rescale vs cold")?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_predicted_time_is_monotone_in_total_batch() {
    check(
        "optperf-monotone-in-B",
        60,
        |rng| random_model(rng),
        |model| {
            let mut prev = 0.0;
            for b in [32.0, 64.0, 128.0, 256.0, 512.0, 1024.0] {
                let a = optperf::solve(model, b).map_err(|e| e.to_string())?;
                ensure(
                    a.t_pred >= prev - 1e-9,
                    format!("T({b}) = {} < T(prev) = {prev}", a.t_pred),
                )?;
                prev = a.t_pred;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_integer_alloc_preserves_total_and_caps() {
    check(
        "integer-alloc",
        200,
        |rng| {
            let n = 1 + rng.below(20) as usize;
            let total = 1 + rng.below(5000);
            let raw: Vec<f64> = (0..n).map(|_| rng.f64() * 500.0).collect();
            let scale = total as f64 / raw.iter().sum::<f64>().max(1e-9);
            let want: Vec<f64> = raw.iter().map(|x| x * scale).collect();
            // caps generous enough to hold the total
            let caps: Vec<u64> = (0..n).map(|_| total).collect();
            (want, total, caps)
        },
        |(want, total, caps)| {
            let out = optperf::integer_alloc(want, *total, caps);
            ensure(out.iter().sum::<u64>() == *total, "sum == total")?;
            ensure(
                out.iter().zip(caps).all(|(b, c)| b <= c),
                "caps respected",
            )
        },
    );
}

#[test]
fn prop_gns_weights_sum_to_one_any_heterogeneity() {
    check(
        "gns-weights",
        150,
        |rng| {
            let n = 2 + rng.below(20) as usize;
            let b: Vec<f64> = (0..n).map(|_| 1.0 + rng.below(128) as f64).collect();
            b
        },
        |b| {
            let (wg, ws) = gns::optimal_weights(b).map_err(|e| e.to_string())?;
            close(wg.iter().sum::<f64>(), 1.0, 1e-8, "Σw_G")?;
            close(ws.iter().sum::<f64>(), 1.0, 1e-8, "Σw_S")?;
            ensure(wg.iter().all(|x| x.is_finite()), "finite w_G")?;
            ensure(ws.iter().all(|x| x.is_finite()), "finite w_S")
        },
    );
}

#[test]
fn prop_planner_plans_are_always_valid() {
    // routing/batching/state invariant: whatever the planner does across
    // epochs, the plan sums to its declared total and respects caps
    check(
        "planner-valid-plans",
        25,
        |rng| {
            let n = 2 + rng.below(10) as usize;
            let cluster = random_cluster(rng, n);
            let seed = rng.next_u64();
            (cluster, seed)
        },
        |(cluster, seed)| {
            let w = workload::cifar10();
            let caps: Vec<u64> =
                cluster.nodes.iter().map(|nd| w.max_local_batch(nd)).collect();
            let opts = BuildOptions {
                b_max: Some(w.b_max.min(caps.iter().sum::<u64>())),
                ..Default::default()
            };
            let mut planner = SystemRegistry::builtin()
                .build("cannikin", cluster, &w, &opts)
                .map_err(|e| e.to_string())?;
            let mut sim = ClusterSim::new(cluster, &w, *seed);
            let mut phi = w.phi0;
            for e in 0..10 {
                let plan = planner.plan_epoch(e, phi);
                ensure(
                    plan.local.iter().sum::<u64>() == plan.total,
                    format!("epoch {e}: sum {:?} != {}", plan.local, plan.total),
                )?;
                ensure(
                    plan.local.iter().zip(&caps).all(|(b, c)| b <= c),
                    format!("epoch {e}: cap violated {:?} vs {caps:?}", plan.local),
                )?;
                let out = sim.step(&plan.local_f64());
                planner.observe_epoch(&out.per_node, out.t_batch);
                phi *= 1.5;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_even_split_is_fair_and_exact() {
    check(
        "even-split",
        200,
        |rng| (1 + rng.below(10_000), 1 + rng.below(64) as usize),
        |(total, n)| {
            let s = even_split(*total, *n);
            ensure(s.iter().sum::<u64>() == *total, "sum")?;
            let max = *s.iter().max().unwrap();
            let min = *s.iter().min().unwrap();
            ensure(max - min <= 1, "balance")
        },
    );
}

// ---------------------------------------------------------------------------
// elastic: churn-trace JSON round-trips + membership state integrity
// ---------------------------------------------------------------------------

fn random_device(rng: &mut Rng) -> DeviceProfile {
    DeviceProfile::new(
        ["A100", "V100", "RTX6000", "oddball-η"][rng.below(4) as usize],
        0.05 + rng.f64() * 5.0,
        1.0 + rng.f64() * 80.0,
        rng.f64() * 0.2,
        rng.f64() * 0.05,
    )
}

/// Factors deliberately include extremes the membership layer would
/// reject — serialization must preserve them verbatim regardless.
fn random_factor(rng: &mut Rng) -> f64 {
    match rng.below(7) {
        0 => 1e-12,
        1 => 1e-6,
        2 => 4.0,
        3 => 1e9,
        4 => 12345.678901,
        5 => 1.0,
        _ => rng.f64() * 4.0,
    }
}

/// In-epoch offsets across the whole domain, with heavy weight on the
/// boundary (the common case) and awkward shapes near the edges.
fn random_frac(rng: &mut Rng) -> f64 {
    match rng.below(6) {
        0 | 1 => 0.0,
        2 => 0.5,
        3 => f64::EPSILON,
        4 => 1.0 - f64::EPSILON,
        _ => rng.f64() * 0.999,
    }
}

fn random_trace(rng: &mut Rng) -> ChurnTrace {
    let n_ev = rng.below(14) as usize;
    let mut events = Vec::new();
    for _ in 0..n_ev {
        // epochs intentionally out of order (from_json must sort stably)
        let epoch = rng.below(10_000) as usize;
        let node = rng.below(32) as usize;
        let event = match rng.below(5) {
            0 => ClusterEvent::NodeJoin {
                device: random_device(rng),
                uid: if rng.below(2) == 0 { Some(rng.below(1 << 50)) } else { None },
            },
            1 => ClusterEvent::NodeLeave { node },
            2 => ClusterEvent::Preempt { node },
            3 => ClusterEvent::SlowDown { node, factor: random_factor(rng) },
            _ => ClusterEvent::Recover { node },
        };
        events.push(TimedEvent { epoch, frac: random_frac(rng), event });
    }
    ChurnTrace { name: format!("fuzz-{}", rng.below(1000)), events }
}

/// Stable `(epoch, frac)` sort — the order `from_json` promises.
fn sort_by_position(events: &mut [TimedEvent]) {
    events.sort_by(|a, b| a.epoch.cmp(&b.epoch).then(a.frac.total_cmp(&b.frac)));
}

#[test]
fn prop_churn_trace_json_roundtrips_across_all_event_kinds() {
    check(
        "trace-json-roundtrip",
        150,
        |rng| random_trace(rng),
        |t| {
            let pretty = t.to_json().to_string_pretty();
            let back = ChurnTrace::from_json(&Json::parse(&pretty).map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            // from_json stably sorts by (epoch, frac); compare against the
            // stably sorted original (same-position order is preserved)
            let mut want = t.clone();
            sort_by_position(&mut want.events);
            ensure(back == want, format!("roundtrip mismatch:\n{want:?}\nvs\n{back:?}"))?;
            ensure(back.counts() == t.counts(), "per-kind counts must survive")?;
            // serialization is deterministic and idempotent
            let again = Json::parse(&back.to_json().to_string_pretty())
                .map_err(|e| e.to_string())?;
            ensure(
                ChurnTrace::from_json(&again).map_err(|e| e.to_string())? == want,
                "second roundtrip must be a fixed point",
            )
        },
    );
}

#[test]
fn prop_push_order_at_same_position_survives_build_and_json_roundtrip() {
    // the binary-search insertion in ChurnTrace::push_at must preserve
    // the relative push order of events sharing an (epoch, frac) position
    // — and a JSON round trip must not reshuffle them either.  Recover
    // events carry a unique node id as a sequence tag.
    check(
        "trace-push-order",
        150,
        |rng| {
            let n_ev = 2 + rng.below(20) as usize;
            // few distinct positions → many same-position collisions
            let pushes: Vec<(usize, f64, usize)> = (0..n_ev)
                .map(|tag| (rng.below(3) as usize, [0.0, 0.5][rng.below(2) as usize], tag))
                .collect();
            pushes
        },
        |pushes| {
            let mut t = ChurnTrace::new("order");
            for &(epoch, frac, tag) in pushes {
                t.push_at(epoch, frac, ClusterEvent::Recover { node: tag });
            }
            // the built timeline equals the stable sort of the push list
            let mut want = ChurnTrace::new("order");
            want.events = pushes
                .iter()
                .map(|&(epoch, frac, tag)| TimedEvent {
                    epoch,
                    frac,
                    event: ClusterEvent::Recover { node: tag },
                })
                .collect();
            sort_by_position(&mut want.events);
            ensure(t.events == want.events, format!("push order broken:\n{t:?}\nvs\n{want:?}"))?;
            // …and survives serialization byte-exactly
            let back = ChurnTrace::from_json(
                &Json::parse(&t.to_json().to_string_pretty()).map_err(|e| e.to_string())?,
            )
            .map_err(|e| e.to_string())?;
            ensure(back.events == t.events, "JSON round trip reshuffled same-position events")
        },
    );
}

#[test]
fn prop_elastic_membership_never_corrupts_state() {
    // whatever garbage the event stream throws at it — stale indices,
    // duplicate uids, invalid factors, attempts to empty the cluster —
    // the view either applies an event or rejects it atomically
    check(
        "elastic-membership-fuzz",
        60,
        |rng| {
            let n = 2 + rng.below(5) as usize;
            let cluster = random_cluster(rng, n);
            let seed = rng.next_u64();
            (cluster, seed)
        },
        |(cluster, seed)| {
            let mut rng = Rng::new(*seed);
            let mut ec = ElasticCluster::new(cluster);
            for _ in 0..60 {
                let n = ec.n();
                let node = rng.below((n + 2) as u64) as usize; // often stale
                let ev = match rng.below(5) {
                    0 => ClusterEvent::NodeJoin {
                        device: random_device(&mut rng),
                        uid: if rng.below(3) == 0 { Some(rng.below(8)) } else { None },
                    },
                    1 => ClusterEvent::NodeLeave { node },
                    2 => ClusterEvent::Preempt { node },
                    3 => ClusterEvent::SlowDown {
                        node,
                        factor: rng.f64() * 6.0 - 0.5, // sometimes invalid
                    },
                    _ => ClusterEvent::Recover { node },
                };
                let _ = ec.apply(&ev); // errors are fine; corruption is not
                ensure(ec.n() >= 1, "cluster can never empty")?;
                let spec = ec.spec();
                ensure(spec.n() == ec.n(), "spec width matches the view")?;
                ensure(ec.uids().len() == ec.n(), "one uid per node")?;
                let mut uids = ec.uids().to_vec();
                uids.sort_unstable();
                uids.dedup();
                ensure(uids.len() == ec.n(), "uids stay unique")?;
                for i in 0..ec.n() {
                    let f = ec.slow_factor(i);
                    ensure(f > 0.0 && f <= 4.0, format!("slow factor {f} out of range"))?;
                    ensure(
                        spec.nodes[i].device.speed > 0.0,
                        "effective speeds stay positive",
                    )?;
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// checkpoint-interval modeling: wasted-work invariants
// ---------------------------------------------------------------------------

/// Run cannikin on cluster A / cifar10 through `trace` with the given
/// scenario knobs (registry-built, like every production caller).
fn run_ckpt(trace: &ChurnTrace, cfg: &ScenarioConfig) -> RunReport {
    let c = cannikin::cluster::cluster_a();
    let w = workload::cifar10();
    let mut sys = SystemRegistry::builtin()
        .build("cannikin", &c, &w, &BuildOptions::default())
        .expect("builtin system");
    api::run(&c, &w, trace, sys.as_mut(), cfg)
}

fn one_preempt(epoch: usize, frac: f64, node: usize) -> ChurnTrace {
    let mut t = ChurnTrace::new("one-preempt");
    t.push_at(epoch, frac, ClusterEvent::Preempt { node });
    t
}

#[test]
fn prop_wasted_work_is_monotone_in_time_since_checkpoint() {
    // with a period longer than the whole run the only restore point is
    // the initial state, so the rollback charge is exactly the active
    // training time at the event — later events must never waste less
    check(
        "ckpt-wasted-monotone",
        8,
        |rng| {
            let seed = 1 + rng.below(1000);
            let epoch = 5 + rng.below(20) as usize;
            let f_lo = 0.05 + rng.f64() * 0.4;
            let f_hi = f_lo + 0.05 + rng.f64() * (0.9 - f_lo - 0.05);
            let node = rng.below(3) as usize;
            (seed, epoch, f_lo, f_hi, node)
        },
        |&(seed, epoch, f_lo, f_hi, node)| {
            let cfg = ScenarioConfig {
                max_epochs: 40,
                seed,
                ckpt: CheckpointPolicy { period_secs: 1e15, write_cost_secs: 0.0 },
                ..Default::default()
            };
            let lo = run_ckpt(&one_preempt(epoch, f_lo, node), &cfg);
            let hi = run_ckpt(&one_preempt(epoch, f_hi, node), &cfg);
            ensure(lo.events_applied == 1 && hi.events_applied == 1, "preempt must apply")?;
            ensure(lo.wasted_work_secs > 0.0, "a rollback must be charged")?;
            ensure(
                hi.wasted_work_secs >= lo.wasted_work_secs,
                format!(
                    "wasted({f_hi}) = {} < wasted({f_lo}) = {}",
                    hi.wasted_work_secs, lo.wasted_work_secs
                ),
            )?;
            // a full epoch later must strictly dominate both
            let later = run_ckpt(&one_preempt(epoch + 3, f_lo, node), &cfg);
            ensure(
                later.wasted_work_secs > hi.wasted_work_secs,
                format!(
                    "wasted(epoch {} ) = {} <= wasted(epoch {epoch}) = {}",
                    epoch + 3,
                    later.wasted_work_secs,
                    hi.wasted_work_secs
                ),
            )
        },
    );
}

#[test]
fn prop_single_preempt_rollback_is_bounded_by_one_checkpoint_period() {
    // checkpoints fire at every period multiple the active clock crosses,
    // so a single abrupt departure can never lose more than one period of
    // work (the in-flight part is inside that bound by construction) —
    // and the write overhead is exactly (checkpoints taken) × cost
    check(
        "ckpt-wasted-bounded",
        8,
        |rng| {
            let seed = 1 + rng.below(1000);
            let epoch = 5 + rng.below(20) as usize;
            let frac = 0.1 + rng.f64() * 0.8;
            let period = 1.0 + rng.f64() * 999.0;
            (seed, epoch, frac, period)
        },
        |&(seed, epoch, frac, period)| {
            let cfg = ScenarioConfig {
                max_epochs: 40,
                seed,
                ckpt: CheckpointPolicy { period_secs: period, write_cost_secs: 2.0 },
                ..Default::default()
            };
            let r = run_ckpt(&one_preempt(epoch, frac, 1), &cfg);
            ensure(r.events_applied == 1, "preempt must apply")?;
            ensure(
                r.wasted_work_secs <= period + 1e-9,
                format!("wasted {} exceeds the period {period}", r.wasted_work_secs),
            )?;
            ensure(
                r.checkpoint_overhead_secs == r.checkpoints_taken as f64 * 2.0,
                format!(
                    "overhead {} != {} checkpoints x 2.0s",
                    r.checkpoint_overhead_secs, r.checkpoints_taken
                ),
            )
        },
    );
}

#[test]
fn prop_zero_period_reproduces_the_legacy_run_bit_for_bit() {
    // period 0 must be indistinguishable from the pre-checkpoint driver:
    // identical reports in every field (the write cost is inert), zero
    // checkpoint accounting — under Boundary replanning, the legacy mode
    check(
        "ckpt-zero-period-legacy",
        6,
        |rng| 1 + rng.below(1000),
        |&seed| {
            let c = cannikin::cluster::cluster_a();
            let trace = cannikin::elastic::spot_instance(&c, 60, seed);
            let legacy = ScenarioConfig { max_epochs: 60, seed, ..Default::default() };
            let zeroed = ScenarioConfig {
                ckpt: CheckpointPolicy { period_secs: 0.0, write_cost_secs: 7.5 },
                replan: ReplanTiming::Boundary,
                ..legacy
            };
            let a = run_ckpt(&trace, &legacy);
            let b = run_ckpt(&trace, &zeroed);
            ensure(a == b, "period 0 diverged from the legacy run")?;
            ensure(b.checkpoints_taken == 0, "no checkpoints may fire at period 0")?;
            ensure(b.checkpoint_overhead_secs == 0.0, "no write cost at period 0")
        },
    );
}

// ---------------------------------------------------------------------------
// deterministic tracing: ledger + determinism invariants over random runs
// ---------------------------------------------------------------------------

/// `run_ckpt` with a ring tracer attached.
fn run_ckpt_traced(trace: &ChurnTrace, cfg: &ScenarioConfig) -> (RunReport, Vec<Json>) {
    let c = cannikin::cluster::cluster_a();
    let w = workload::cifar10();
    let mut sys = SystemRegistry::builtin()
        .build("cannikin", &c, &w, &BuildOptions::default())
        .expect("builtin system");
    let (mut tracer, handle) = Tracer::ring(1_000_000);
    let r = api::run_traced(&c, &w, trace, sys.as_mut(), cfg, &mut tracer);
    tracer.finish().expect("ring sink cannot fail");
    (r, handle.records())
}

/// Random short scenarios: any seed, any preemption position, any finite
/// checkpoint period (including none) and either replan timing.
fn random_traced_case(rng: &mut Rng) -> (ChurnTrace, ScenarioConfig) {
    let seed = 1 + rng.below(1000);
    let trace = match rng.below(3) {
        0 => one_preempt(5 + rng.below(20) as usize, random_frac(rng).min(0.95), rng.below(3) as usize),
        1 => cannikin::elastic::spot_instance(&cannikin::cluster::cluster_a(), 60, seed),
        _ => ChurnTrace::new("quiet"),
    };
    let cfg = ScenarioConfig {
        max_epochs: 60,
        seed,
        ckpt: if rng.below(2) == 0 {
            CheckpointPolicy { period_secs: 1.0 + rng.f64() * 999.0, write_cost_secs: 2.0 }
        } else {
            CheckpointPolicy::default()
        },
        replan: [ReplanTiming::Boundary, ReplanTiming::Immediate][rng.below(2) as usize],
        ..Default::default()
    };
    (trace, cfg)
}

#[test]
fn prop_trace_ledgers_reconcile_with_the_report_bit_for_bit() {
    // the trace IS the ledger: for any scenario shape, summing the waste
    // records reproduces wasted_work_secs exactly (same f64 bits — the
    // driver emits the per-epoch addends in summation order), and the
    // ckpt/replan deltas reproduce their counters
    check(
        "trace-ledger-reconciles",
        10,
        |rng| random_traced_case(rng),
        |(trace, cfg)| {
            let (r, recs) = run_ckpt_traced(trace, cfg);
            let s = tools::summarize(&recs).map_err(|e| e.to_string())?;
            ensure(
                s.wasted_work_secs.to_bits() == r.wasted_work_secs.to_bits(),
                format!(
                    "waste ledger {} != report {}",
                    s.wasted_work_secs, r.wasted_work_secs
                ),
            )?;
            ensure(
                s.ckpt_writes == r.checkpoints_taken,
                format!("ckpt ledger {} != report {}", s.ckpt_writes, r.checkpoints_taken),
            )?;
            ensure(
                s.replans == r.replans,
                format!("replan ledger {} != report {}", s.replans, r.replans),
            )?;
            ensure(
                s.replans_immediate == r.replans_immediate,
                format!("{} != {}", s.replans_immediate, r.replans_immediate),
            )?;
            // the embedded rollups agree with the same trace
            let d = r.driver_stats.as_ref().ok_or("traced run must embed driver stats")?;
            ensure(d.ckpt_writes == r.checkpoints_taken, "driver stats ckpt mismatch")?;
            ensure(d.segments >= r.rows.len(), "at least one segment per epoch")?;
            let sv = r.solver_stats.as_ref().ok_or("traced run must embed solver stats")?;
            ensure(
                (s.solver.calls, s.solver.solves) == (sv.calls, sv.solves),
                format!("solver ledger {:?} != report {:?}", s.solver, sv),
            )
        },
    );
}

#[test]
fn prop_traces_are_deterministic_per_seed_once_wall_is_stripped() {
    check(
        "trace-deterministic",
        8,
        |rng| random_traced_case(rng),
        |(trace, cfg)| {
            let (ra, ta) = run_ckpt_traced(trace, cfg);
            let (rb, tb) = run_ckpt_traced(trace, cfg);
            ensure(ra == rb, "reports must be deterministic")?;
            match tools::diff(&ta, &tb) {
                None => Ok(()),
                Some(d) => Err(format!("same-seed trace divergence:\n{}", d.render())),
            }
        },
    );
}

#[test]
fn prop_simulator_time_increases_with_any_nodes_batch() {
    check(
        "sim-monotone",
        40,
        |rng| {
            let model = random_model(rng);
            let b: Vec<f64> = (0..model.n()).map(|_| 4.0 + rng.f64() * 64.0).collect();
            let node = rng.below(model.n() as u64) as usize;
            (model, b, node)
        },
        |(model, b, node)| {
            let mut sim = cannikin::simulator::ClusterSim::noiseless(
                model.nodes.clone(),
                model.gamma,
                model.t_comm,
                model.n_buckets,
            );
            let t1 = sim.step(b).t_batch;
            let mut b2 = b.clone();
            b2[*node] += 200.0;
            let t2 = sim.step(&b2).t_batch;
            ensure(t2 >= t1 - 1e-9, format!("t2 {t2} < t1 {t1}"))
        },
    );
}

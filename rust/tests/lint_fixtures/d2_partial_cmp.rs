// Fixture: D2 must fire — partial_cmp chained into unwrap/expect in a sort.

pub fn sort_desc(v: &mut Vec<f64>) {
    v.sort_by(|a, b| b.partial_cmp(a).unwrap());
}

pub fn sort_multiline(v: &mut [f64]) {
    v.sort_by(|a, b| {
        a.partial_cmp(b)
            .expect("comparable")
    });
}

// Fixture: D1 must fire — wall-clock read in unregistered library code.
// The driver lints this under the virtual path rust/src/simulator/convergence.rs.

pub fn elapsed_secs() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

use std::collections::HashMap;

// Fixture: D3 must fire — an unordered map in an emission module means
// iteration order is emission order.  The driver lints this under the
// virtual path rust/src/obs/emit.rs.
pub fn emit(rows: &HashMap<String, f64>) -> String {
    let mut out = String::new();
    for (k, v) in rows {
        out.push_str(&format!("{k}={v}\n"));
    }
    out
}

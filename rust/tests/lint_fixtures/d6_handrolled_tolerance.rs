// Fixture: D6 must fire — hand-rolled absent-field tolerance in a report
// reader.  The driver lints this under the virtual path rust/src/api/report.rs.

pub fn parse(j: &Json) -> usize {
    let rounds = match j.get("rounds") {
        None | Some(Json::Null) => 0,
        Some(v) => v.as_usize().unwrap_or(0),
    };
    let extra = j.get("extra").and_then(|v| v.as_usize().ok()).unwrap_or(0);
    rounds + extra
}

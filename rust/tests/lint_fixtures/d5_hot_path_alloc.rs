// Fixture: D5 must fire — panic and allocation sites inside a registered
// hot function.  The driver lints this under the virtual path
// rust/src/optperf/packed.rs.

pub fn solve_hint_into(xs: &[f64], out: &mut Vec<f64>) {
    let first = xs.first().unwrap();
    out.push(*first);
    let copy = xs.to_vec();
    let _ = copy[0];
}

pub fn cold_path(xs: &[f64]) -> f64 {
    // not a registered hot fn: this unwrap must NOT fire
    *xs.first().unwrap()
}

// Fixture: D4 must fire — a planner constructed outside the registry.

pub fn sneaky() {
    let _p = CannikinPlanner::new(Default::default());
}

#[cfg(test)]
mod tests {
    // constructions below the test marker are allowed and must NOT fire
    fn in_tests() {
        let _p = CannikinPlanner::new(Default::default());
    }
}

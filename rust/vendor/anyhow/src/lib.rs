//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline image vendors no external crates, so this shim provides the
//! exact API surface the cannikin crate uses: [`Error`] (a message plus a
//! cause chain), [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.  Semantics follow real anyhow
//! where it matters here:
//!
//! * `{e}` prints the top message, `{e:#}` prints `top: cause: cause`,
//!   `{e:?}` prints the message plus a `Caused by:` list;
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`] (and `Error` propagates through `?` via the std identity
//!   `From`, which is why `Error` deliberately does **not** implement
//!   `std::error::Error`).

use std::fmt;

/// An error: a top-level message plus an outermost-first cause chain.
pub struct Error {
    msg: String,
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), chain: Vec::new() }
    }

    fn from_std(e: &dyn std::error::Error) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), chain }
    }

    /// Wrap with a new outer message; the previous message joins the chain.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: c.to_string(), chain }
    }

    /// The outermost message (the `{e}` rendering).
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for c in &self.chain {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// No overlap with std's reflexive `From<T> for T`: `Error` does not
// implement `std::error::Error`, so E can never be `Error` here.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;

    /// Either a std error (converted, keeping its source chain) or an
    /// [`Error`] passed through.  Same coherence trick as real anyhow.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// `.context("...")` / `.with_context(|| ...)` on `Result`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(c)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(f())),
        }
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i64> {
        let n: i64 = s.parse()?;
        ensure!(n >= 0, "negative number {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_num("42").unwrap(), 42);
        assert!(parse_num("nope").is_err());
        assert!(parse_num("-3").is_err());
    }

    #[test]
    fn context_chains_and_formats() {
        let base: Result<()> = Err(anyhow!("disk on fire"));
        let e = base.context("loading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: disk on fire");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("disk on fire"), "{dbg}");
    }

    #[test]
    fn with_context_on_std_error() {
        let r: std::result::Result<i32, std::num::ParseIntError> = "x".parse::<i32>();
        let e = r.with_context(|| format!("parsing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "parsing x");
        assert!(format!("{e:#}").contains(": "));
    }

    #[test]
    fn bail_and_ensure_return_errors() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 7);
            }
            ensure!(1 + 1 == 2);
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(format!("{}", f(true).unwrap_err()), "flagged 7");
    }
}

//! Host-side stand-in for the `xla` (PJRT) crate.
//!
//! The offline image does not ship the XLA/PJRT native bindings, so this
//! crate reproduces exactly the API surface `cannikin::runtime` uses.  The
//! split is deliberate:
//!
//! * **Literals are real.**  `Literal` is a plain host tensor (f32/i32 data
//!   + dims), so every host-side path — `scalar`, `vec1`, `reshape`,
//!   `to_vec`, `array_shape`, and the literal round-trip helpers built on
//!   them — behaves like the real crate and stays fully tested.
//! * **Execution is absent.**  `PjRtClient::cpu()` returns an error, so
//!   anything that would compile or run HLO fails fast with a clear
//!   message instead of silently fabricating numerics.  The AOT artifacts
//!   work end-to-end only in an image with the real `xla` crate; the
//!   runtime tests already skip themselves when `artifacts/` is absent.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type; rendered with `{:?}` by callers.
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const NO_BACKEND: &str =
    "PJRT backend not available: this build uses the in-tree xla stub (host literals only); \
     build against the real xla crate to execute AOT artifacts";

/// A host tensor (or tuple of tensors).  Mirrors the real crate's shape
/// behaviour for the element types cannikin uses (f32, i32).
#[derive(Debug)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

/// Element types the stub supports.
pub trait NativeType: Copy {
    fn scalar_literal(self) -> Literal;
    fn vec1_literal(data: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn scalar_literal(self) -> Literal {
        Literal::F32 { data: vec![self], dims: Vec::new() }
    }
    fn vec1_literal(data: &[f32]) -> Literal {
        Literal::F32 { data: data.to_vec(), dims: vec![data.len() as i64] }
    }
    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error::new(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    fn scalar_literal(self) -> Literal {
        Literal::I32 { data: vec![self], dims: Vec::new() }
    }
    fn vec1_literal(data: &[i32]) -> Literal {
        Literal::I32 { data: data.to_vec(), dims: vec![data.len() as i64] }
    }
    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error::new(format!("literal is not i32: {other:?}"))),
        }
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        v.scalar_literal()
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1_literal(data)
    }

    fn numel(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(items) => items.iter().map(|l| l.numel()).sum(),
        }
    }

    /// New literal with the same data and the given dims (numel must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 {
            return Err(Error::new(format!("negative dim in {dims:?}")));
        }
        if want as usize != self.numel() {
            return Err(Error::new(format!(
                "reshape {:?} wants {want} elements, literal has {}",
                dims,
                self.numel()
            )));
        }
        match self {
            Literal::F32 { data, .. } => {
                Ok(Literal::F32 { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::I32 { data, .. } => {
                Ok(Literal::I32 { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::Tuple(_) => Err(Error::new("cannot reshape a tuple literal")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(items) => Ok(items),
            other => Err(Error::new(format!("literal is not a tuple: {other:?}"))),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::F32 { dims, .. } | Literal::I32 { dims, .. } => {
                Ok(ArrayShape { dims: dims.clone() })
            }
            Literal::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
        }
    }
}

/// Array shape (dims only — that is all cannikin reads).
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::new(format!("cannot parse HLO {path:?}: {NO_BACKEND}")))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client — always unavailable in the stub.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(NO_BACKEND))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(NO_BACKEND))
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(NO_BACKEND))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(NO_BACKEND))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let lit = Literal::vec1(&data).reshape(&[2, 3]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 3]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let data = vec![5i32, 6, 7, 8];
        let lit = Literal::vec1(&data).reshape(&[4, 1]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn scalar_has_rank_zero() {
        let lit = Literal::scalar(1.5f32);
        assert!(lit.array_shape().unwrap().dims().is_empty());
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.5]);
    }

    #[test]
    fn reshape_checks_numel() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn backend_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}

//! Bench: Fig. 10 end-to-end — batch-time evaluation of the four systems
//! across the batch-size sweep (the harness that regenerates the figure),
//! plus per-step simulator throughput.

use cannikin::benchkit::{report, Bencher};
use cannikin::cluster;
use cannikin::figures;
use cannikin::optperf;
use cannikin::simulator::{workload, ClusterSim};

fn main() {
    let b = Bencher::new(2, 10);
    let c = cluster::cluster_b();
    let w = workload::imagenet();
    let model = w.cluster_model(&c);

    let r = b.run("fig10/full-figure (5 workloads x 8 B x 4 systems)", || {
        figures::fig10().unwrap()
    });
    report(&r);

    let alloc = optperf::solve(&model, 1024.0).unwrap();
    let mut sim = ClusterSim::new(&c, &w, 3);
    let r = b.run("simulator/step/16-node", || sim.step(&alloc.batch_sizes));
    report(&r);
}

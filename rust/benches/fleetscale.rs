//! Bench: fleet-scale scaling sweep (ROADMAP item 1) — the three hot
//! paths the 1k–100k-node generators stress (membership event apply,
//! detector end-of-epoch, ledger round diff), fleet/trace generation
//! itself, and one full spot-churn scenario through the unified driver.
//!
//! `--quick` (CI fleet-smoke) runs n ∈ {64, 1k} and a 1k-node, 50-epoch
//! scenario; the full sweep runs n ∈ {64, 1k, 10k, 100k} plus the
//! acceptance scenario (10k nodes, 200 epochs).  Results land in
//! `BENCH_fleetscale.json` — see PERF_fleetscale.md for the per-path
//! before/after complexity story.

use cannikin::api::{self, BuildOptions, SystemRegistry};
use cannikin::benchkit::{report, Bencher, Snapshot};
use cannikin::elastic::{
    self, DetectionMode, DetectorConfig, ElasticCluster, HazardCurve, ScenarioConfig,
    StragglerDetector,
};
use cannikin::sched::FleetLedger;
use cannikin::simulator::timing::NodeBatchObs;
use cannikin::simulator::workload;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[64, 1000] } else { &[64, 1000, 10_000, 100_000] };
    let b = if quick { Bencher::new(1, 3) } else { Bencher::new(1, 5) };
    let hazard = HazardCurve::spot();
    let mut snap = Snapshot::new("fleetscale");

    for &n in sizes {
        // 100k-node membership replay is O(events · n) memmove by
        // nature; trim its horizon so the full sweep stays minutes, and
        // say so instead of hiding it
        let epochs = if n >= 100_000 { 50 } else { 200 };
        if epochs != 200 {
            println!("n={n}: trace horizon trimmed to {epochs} epochs");
        }
        let cluster = elastic::fleet_cluster(n, 42);
        let trace = elastic::fleet_churn(&cluster, epochs, &hazard, 42).expect("valid hazard");
        println!(
            "n={n}: {} events ({} departures, {} joins) over {epochs} epochs",
            trace.len(),
            trace.counts().departures(),
            trace.counts().joins
        );
        snap.note_num(&format!("events_n{n}"), trace.len() as f64);

        let r = b.run(&format!("fleetscale/fleetgen/n={n}"), || {
            let c = elastic::fleet_cluster(n, 42);
            elastic::fleet_churn(&c, epochs, &hazard, 42).expect("valid hazard")
        });
        report(&r);
        snap.push(&r);

        // hot path 1: ElasticCluster event apply (no per-event clones of
        // the removed set / nominal profiles any more)
        let r = b.run(&format!("fleetscale/membership-apply/n={n}"), || {
            let mut ec = ElasticCluster::new(&cluster);
            for te in &trace.events {
                ec.apply(&te.event).expect("generated traces replay cleanly");
            }
            ec.spec().n()
        });
        report(&r);
        snap.push(&r);

        // hot path 2: StragglerDetector end-of-epoch under a constant
        // plan (the steady state — allocation-free after warm-up)
        let obs: Vec<NodeBatchObs> = (0..n)
            .map(|i| NodeBatchObs {
                b: 32.0,
                a_time: 0.010 + 1e-5 * (i % 7) as f64,
                p_time: 0.020,
                gamma_obs: 0.5,
                t_comm_obs: 0.005,
                finish: 0.035,
            })
            .collect();
        let mut det = StragglerDetector::new(n, DetectorConfig::default());
        let mut epoch = 0usize;
        let r = b.run(&format!("fleetscale/detector-end-epoch/n={n}"), || {
            det.observe(&obs);
            let ev = det.end_epoch(epoch);
            epoch += 1;
            ev.len()
        });
        report(&r);
        snap.push(&r);

        // hot path 3: FleetLedger round diff (sorted-index sync + the
        // conservation check) at steady membership
        let uids: Vec<u64> = (0..n as u64).collect();
        let mut ledger = FleetLedger::new(1);
        ledger.seed(0, &uids);
        let r = b.run(&format!("fleetscale/ledger-round/n={n}"), || {
            let (lost, grants) = ledger.sync(0, &uids);
            ledger.check(&[]);
            (lost, grants)
        });
        report(&r);
        snap.push(&r);
    }

    // full spot-churn scenario through the unified driver — the
    // acceptance run: every epoch exercises ElasticDriver::step, the
    // observation fold, and (Observed mode) the straggler detector
    let (sc_n, sc_epochs) = if quick { (1000, 50) } else { (10_000, 200) };
    let c = elastic::fleet_cluster(sc_n, 7);
    let w = workload::cifar10();
    let sc_trace = elastic::fleet_churn(&c, sc_epochs, &hazard, 7).expect("valid hazard");
    let reg = SystemRegistry::builtin();
    let cfg = ScenarioConfig {
        max_epochs: sc_epochs,
        seed: 7,
        detect: DetectionMode::Observed,
        ..Default::default()
    };
    let mut events_applied = 0usize;
    let sb = Bencher::new(0, 1);
    let r = sb.run(&format!("fleetscale/scenario/even/n={sc_n}-e={sc_epochs}"), || {
        let mut sys = reg.build("even", &c, &w, &BuildOptions::default()).unwrap();
        let rep = api::run(&c, &w, &sc_trace, sys.as_mut(), &cfg);
        events_applied = rep.events_applied;
        rep
    });
    report(&r);
    snap.push(&r);
    println!(
        "scenario: {} nodes, {} epochs, {} trace events, {} applied",
        sc_n,
        sc_epochs,
        sc_trace.len(),
        events_applied
    );

    snap.note_str("mode", if quick { "quick" } else { "full" });
    snap.note_num("scenario_nodes", sc_n as f64);
    snap.note_num("scenario_epochs", sc_epochs as f64);
    snap.note_num("scenario_trace_events", sc_trace.len() as f64);
    snap.note_num("scenario_events_applied", events_applied as f64);
    match snap.save_at_repo_root() {
        Ok(p) => println!("\nbench snapshot written to {}", p.display()),
        Err(e) => eprintln!("\nwarning: could not write bench snapshot: {e:#}"),
    }
}

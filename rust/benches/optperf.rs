//! Bench: Algorithm 1 solve latency vs cluster size — the Table 5
//! overhead claim's microscopic half.  A full candidate-table build
//! (the §4.5 init epoch) is also measured.

use cannikin::benchkit::{report, Bencher, Snapshot};
use cannikin::cluster;
use cannikin::goodput;
use cannikin::optperf;
use cannikin::simulator::workload;
use cannikin::util::rng::Rng;

fn main() {
    let mut snap = Snapshot::new("optperf");
    let b = Bencher::new(5, 50);
    let w = workload::imagenet();
    println!("Algorithm 1 (OptPerf solve):");
    for n in [3usize, 16, 64, 256] {
        let mut rng = Rng::new(n as u64);
        let c = cluster::random_cluster(&mut rng, n);
        let model = w.cluster_model(&c);
        let r = b.run(&format!("optperf/solve/n={n}/B=4096"), || {
            optperf::solve(&model, 4096.0).unwrap()
        });
        report(&r);
        snap.push(&r);
    }
    println!("\ncandidate-table build (§4.5 init epoch, 16 nodes):");
    let c = cluster::cluster_b();
    let model = w.cluster_model(&c);
    let cands = goodput::candidates(w.b0, w.b_max, 6);
    let r = b.run(&format!("optperf/table/{} candidates", cands.len()), || {
        for &bb in &cands {
            optperf::solve(&model, bb as f64).unwrap();
        }
    });
    report(&r);
    snap.push(&r);
    snap.note_str("workload", "imagenet");
    snap.note_num("table_candidates", cands.len() as f64);
    match snap.save_at_repo_root() {
        Ok(p) => println!("\nbench snapshot written to {}", p.display()),
        Err(e) => eprintln!("\nwarning: could not write bench snapshot: {e:#}"),
    }
}

//! Bench: Algorithm 1 solve latency vs cluster size — the Table 5
//! overhead claim's microscopic half, now split three ways per size:
//! cold solve (fresh model, no state), hinted solve (packed workspace +
//! converged §4.5 overlap-state hint — the per-epoch steady state), and
//! delta solve (persistent candidate cache patched for a one-node
//! removal — the elastic re-plan path).  A full candidate-table build
//! (cold vs warm rebuild) rounds out the §4.5 init-epoch claim.
//!
//! `--quick` (CI bench-smoke) trims the sweep to n ∈ {16, 64} with few
//! samples; the full sweep runs 16 → 4096 nodes.  Results land in
//! `BENCH_optperf.json` with `measured: true` — see PERF_optperf.md.

use cannikin::benchkit::{report, Bencher, Snapshot};
use cannikin::cluster;
use cannikin::goodput;
use cannikin::optperf::{self, Allocation, SolveCache, SolverWorkspace};
use cannikin::simulator::workload;
use cannikin::util::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[16, 64] } else { &[16, 64, 256, 1024, 4096] };
    let b = if quick { Bencher::new(1, 5) } else { Bencher::new(5, 50) };

    let mut snap = Snapshot::new("optperf");
    let w = workload::imagenet();

    println!("Algorithm 1 (cold / hinted / delta) vs cluster size:");
    for &n in sizes {
        let mut rng = Rng::new(n as u64);
        let c = cluster::random_cluster(&mut rng, n);
        let model = w.cluster_model(&c);
        // scale the total with n so the per-node average (and therefore
        // the overlap regime mix) is comparable across sizes
        let total = (n as f64) * 16.0;

        let r = b.run(&format!("optperf/cold/n={n}"), || {
            optperf::solve(&model, total).unwrap()
        });
        report(&r);
        snap.push(&r);

        // hinted: reuse one workspace and the converged overlap state —
        // the planner's steady state once the §4.5 cache is warm
        let mut ws = SolverWorkspace::new();
        let mut out = Allocation::empty();
        ws.solve_hint_into(&model, total, None, &mut out).unwrap();
        let hint = out.state;
        let r = b.run(&format!("optperf/hinted/n={n}"), || {
            ws.solve_hint_into(&model, total, Some(hint), &mut out).unwrap();
            out.t_pred
        });
        report(&r);
        snap.push(&r);

        // delta: candidate cache built on the full cluster, one node
        // removed with exact sum-patching, then re-solved on the
        // shrunken model — the elastic membership-change path
        let cands: Vec<u64> = (0..4).map(|i| (total as u64 / 2) << i).collect();
        let mut cache = SolveCache::new();
        let mut scratch = Allocation::empty();
        cache.rebuild(&mut ws, &model, &cands, &mut scratch);
        let mut small = model.clone();
        small.nodes.remove(n / 2);
        let old_ws = ws;
        cache.delta_remove(n / 2, Some(&old_ws));
        let mut dws = SolverWorkspace::new();
        let r = b.run(&format!("optperf/delta/n={n}"), || {
            cache.delta_solve(&mut dws, &small, cands[1], &mut out).unwrap();
            out.t_pred
        });
        report(&r);
        snap.push(&r);
    }

    println!("\ncandidate-table build (§4.5 init epoch, 16 nodes):");
    let c = cluster::cluster_b();
    let model = w.cluster_model(&c);
    let cands = goodput::candidates(w.b0, w.b_max, 6);
    let r = b.run(&format!("optperf/table-cold/{} candidates", cands.len()), || {
        for &bb in &cands {
            optperf::solve(&model, bb as f64).unwrap();
        }
    });
    report(&r);
    snap.push(&r);

    // warm rebuild: invalidate keeps the entries as hints, so each
    // rebuild is mostly one linear solve per candidate — the
    // fingerprint-drift re-plan path
    let mut ws = SolverWorkspace::new();
    let mut cache = SolveCache::new();
    let mut scratch = Allocation::empty();
    cache.rebuild(&mut ws, &model, &cands, &mut scratch);
    let r = b.run(&format!("optperf/table-warm/{} candidates", cands.len()), || {
        cache.invalidate();
        cache.rebuild(&mut ws, &model, &cands, &mut scratch)
    });
    report(&r);
    snap.push(&r);

    snap.note_str("workload", "imagenet");
    snap.note_num("table_candidates", cands.len() as f64);
    snap.note_str("mode", if quick { "quick" } else { "full" });
    match snap.save_at_repo_root() {
        Ok(p) => println!("\nbench snapshot written to {}", p.display()),
        Err(e) => eprintln!("\nwarning: could not write bench snapshot: {e:#}"),
    }
}

//! Bench: Fig. 8 end-to-end — full convergence-time comparison for one
//! workload (the figure harness row), plus the raw convergence simulator.

use cannikin::baselines::System;
use cannikin::benchkit::{report, Bencher};
use cannikin::cluster;
use cannikin::coordinator::{BatchPolicy, CannikinPlanner};
use cannikin::figures;
use cannikin::simulator::workload;

fn main() {
    let b = Bencher::new(1, 5);
    let c = cluster::cluster_b();
    let w = workload::cifar10();
    let r = b.run("fig8/one-row (cifar10, 4 systems)", || {
        for mut sys in [
            Box::new(CannikinPlanner::new(c.n(), w.b0, w.b_max, w.n_buckets, BatchPolicy::Adaptive))
                as Box<dyn System>,
        ] {
            figures::run_system(&c, &w, sys.as_mut(), 2000, 3);
        }
    });
    report(&r);
    let mut sys = CannikinPlanner::new(c.n(), w.b0, w.b_max, w.n_buckets, BatchPolicy::Adaptive);
    let r = b.run("run_system/cannikin/cifar10/2000-epochs", || {
        figures::run_system(&c, &w, &mut sys, 2000, 3)
    });
    report(&r);
}

//! Bench: Fig. 8 end-to-end — full convergence-time comparison for one
//! workload (the figure harness row), plus the raw unified driver.
//! Systems come from the registry; runs go through `api::run_static`
//! (the same `ElasticDriver` path the elastic scenarios use).

use cannikin::api::{run_static, BuildOptions, SystemRegistry};
use cannikin::benchkit::{report, Bencher};
use cannikin::cluster;
use cannikin::simulator::workload;

fn main() {
    let b = Bencher::new(1, 5);
    let c = cluster::cluster_b();
    let w = workload::cifar10();
    let reg = SystemRegistry::builtin();
    let r = b.run("fig8/one-row (cifar10, 4 systems)", || {
        for name in ["cannikin", "adaptdl", "lbbsp", "ddp"] {
            let mut sys = reg.build(name, &c, &w, &BuildOptions::default()).unwrap();
            run_static(&c, &w, sys.as_mut(), 2000, 3);
        }
    });
    report(&r);
    let mut sys = reg.build("cannikin", &c, &w, &BuildOptions::default()).unwrap();
    let r = b.run("run_static/cannikin/cifar10/2000-epochs", || {
        run_static(&c, &w, sys.as_mut(), 2000, 3)
    });
    report(&r);
}

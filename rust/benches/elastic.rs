//! Bench: elastic time-to-target under the spot-instance churn preset —
//! cannikin (warm replan) vs the cold-restart ablation vs the naive
//! even-re-split baseline vs static DDP, plus the runner's own wall time.
//! Systems come from the registry; every run goes through the unified
//! driver (`api::run`).  Registered in benchkit (harness = false); rows
//! append to the table the EXPERIMENTS notes quote.

use cannikin::api::{self, BuildOptions, RunReport, SystemRegistry};
use cannikin::benchkit::{report, Bencher, Snapshot, Table};
use cannikin::util::json::Json;
use cannikin::cluster;
use cannikin::elastic::{
    self, CheckpointPolicy, DetectionMode, ReplanTiming, ScenarioConfig,
};
use cannikin::simulator::workload;

fn main() {
    let c = cluster::cluster_a();
    let w = workload::cifar10();
    let reg = SystemRegistry::builtin();
    let cfg = ScenarioConfig { max_epochs: 20_000, seed: 7, ..Default::default() };
    let trace = elastic::spot_instance(&c, cfg.max_epochs, cfg.seed);
    let counts = trace.counts();
    println!(
        "spot trace: {} events ({} departures, {} joins, {} slowdowns)",
        trace.len(),
        counts.departures(),
        counts.joins,
        counts.slowdowns
    );

    let mut tbl = Table::new(&[
        "system",
        "time-to-target (sim s)",
        "bootstrap epochs",
        "events",
        "wasted (s)",
    ]);
    let mut run = |label: &str, name: &str| -> RunReport {
        let mut sys = reg.build(name, &c, &w, &BuildOptions::default()).unwrap();
        let r = api::run(&c, &w, &trace, sys.as_mut(), &cfg);
        tbl.row(vec![
            label.to_string(),
            r.time_to_target.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".to_string()),
            r.bootstrap_epochs.to_string(),
            r.events_applied.to_string(),
            format!("{:.1}", r.wasted_work_secs),
        ]);
        r
    };

    let r_warm = run("cannikin-elastic (warm replan)", "cannikin");
    let r_cold = run("cannikin (cold restart ablation)", "cannikin-cold");
    let r_even = run("naive even-re-split", "even");
    let r_ddp = run("static DDP", "ddp");

    tbl.print("Elastic spot-churn, cifar10 on cluster A (lower is better)");

    println!(
        "\nwarm vs cold: bootstrap epochs {} vs {} (strictly fewer: {})",
        r_warm.bootstrap_epochs,
        r_cold.bootstrap_epochs,
        r_warm.bootstrap_epochs < r_cold.bootstrap_epochs,
    );
    if let (Some(tw), Some(te)) = (r_warm.time_to_target, r_even.time_to_target) {
        println!(
            "cannikin-elastic vs naive even-re-split: {:.0}s vs {:.0}s ({:.1}% faster)",
            tw,
            te,
            (1.0 - tw / te) * 100.0
        );
    }
    if let (Some(tw), Some(td)) = (r_warm.time_to_target, r_ddp.time_to_target) {
        println!("cannikin-elastic vs static DDP: {tw:.0}s vs {td:.0}s");
    }

    // ---- straggler detection: oracle replay vs observation-driven
    // (hidden events + StragglerDetector) vs fully hidden (ablation
    // floor), run under a finite checkpoint period so the wasted-work /
    // checkpoint-overhead trade-off shows up next to the detection stats
    let s_trace = elastic::straggler_drift(&c, cfg.max_epochs, cfg.seed);
    let ckpt_period = r_warm
        .rows
        .last()
        .map(|row| row.wall_secs / 50.0)
        .unwrap_or(0.0);
    let ckpt = CheckpointPolicy { period_secs: ckpt_period, write_cost_secs: 2.0 };
    let mut dtbl = Table::new(&[
        "detection mode",
        "epochs-to-target",
        "time-to-target (sim s)",
        "slowdowns (false)",
        "mean lat (epochs)",
        "missed",
        "wasted (s)",
        "ckpt ovh (s)",
    ]);
    for mode in [DetectionMode::Oracle, DetectionMode::Observed, DetectionMode::Off] {
        let mut sys = reg.build("cannikin", &c, &w, &BuildOptions::default()).unwrap();
        let cfg2 = ScenarioConfig { detect: mode, ckpt, ..cfg };
        let r = api::run(&c, &w, &s_trace, sys.as_mut(), &cfg2);
        let (slow, lat, missed) = match &r.detection {
            Some(d) => (
                format!("{} ({})", d.emitted_slowdowns, d.false_slowdowns),
                d.mean_latency().map(|l| format!("{l:.1}")).unwrap_or_else(|| "-".into()),
                d.missed.to_string(),
            ),
            None => ("-".to_string(), "-".to_string(), "-".to_string()),
        };
        dtbl.row(vec![
            mode.name().to_string(),
            r.epochs_to_target().map(|e| e.to_string()).unwrap_or_else(|| "-".into()),
            r.time_to_target.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".into()),
            slow,
            lat,
            missed,
            format!("{:.1}", r.wasted_work_secs),
            format!("{:.1}", r.checkpoint_overhead_secs),
        ]);
    }
    dtbl.print("Straggler drift: oracle vs observation-driven detection (cifar10, cluster A)");

    // ---- checkpoint-interval × replan-timing: the spot preset's abrupt
    // mid-epoch preemptions under a finite checkpoint period, bridged to
    // the boundary (legacy) vs re-solved immediately at the event offset
    let mut ctbl = Table::new(&[
        "replan timing",
        "epochs-to-target",
        "time-to-target (sim s)",
        "wasted (s)",
        "ckpt ovh (s)",
        "immediate replans",
    ]);
    for timing in [ReplanTiming::Boundary, ReplanTiming::Immediate] {
        let mut sys = reg.build("cannikin", &c, &w, &BuildOptions::default()).unwrap();
        let cfg2 = ScenarioConfig { ckpt, replan: timing, ..cfg };
        let r = api::run(&c, &w, &trace, sys.as_mut(), &cfg2);
        ctbl.row(vec![
            timing.name().to_string(),
            r.epochs_to_target().map(|e| e.to_string()).unwrap_or_else(|| "-".into()),
            r.time_to_target.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".into()),
            format!("{:.1}", r.wasted_work_secs),
            format!("{:.1}", r.checkpoint_overhead_secs),
            r.replans_immediate.to_string(),
        ]);
    }
    ctbl.print(&format!(
        "Spot churn under checkpoint period {ckpt_period:.0}s (write cost 2s): \
         boundary vs immediate re-planning"
    ));

    // ---- membership inference: the spot preset's mid-epoch preemptions
    // under Observed are never announced — the missing-heartbeat rule
    // must infer each departure from the node falling silent
    {
        let mut sys = reg.build("cannikin", &c, &w, &BuildOptions::default()).unwrap();
        let cfg2 = ScenarioConfig { detect: DetectionMode::Observed, ..cfg };
        let r = api::run(&c, &w, &trace, sys.as_mut(), &cfg2);
        let d = r.detection.as_ref().expect("observed mode reports detection stats");
        println!(
            "\nspot/observed membership inference: {} preemption(s) inferred \
             ({} false alarms, {} missed), mean lag {} epochs, wasted {:.1}s, \
             reached target: {}",
            d.inferred_preempts,
            d.false_preempts,
            d.missed_preempts,
            d.mean_preempt_latency().map(|l| format!("{l:.1}")).unwrap_or_else(|| "-".into()),
            r.wasted_work_secs,
            r.reached(),
        );
    }

    // wall time of the scenario runner itself (the churn overhead is the
    // quantity a production scheduler would pay per event)
    let mut snap = Snapshot::new("elastic");
    let b = Bencher::new(1, 5);
    let r = b.run("elastic/run/cannikin/spot/20k-epochs", || {
        let mut sys = reg.build("cannikin", &c, &w, &BuildOptions::default()).unwrap();
        api::run(&c, &w, &trace, sys.as_mut(), &cfg)
    });
    report(&r);
    snap.push(&r);

    let r = b.run("elastic/run/cannikin/straggler-observed/20k-epochs", || {
        let mut sys = reg.build("cannikin", &c, &w, &BuildOptions::default()).unwrap();
        let cfg2 = ScenarioConfig { detect: DetectionMode::Observed, ..cfg };
        api::run(&c, &w, &s_trace, sys.as_mut(), &cfg2)
    });
    report(&r);
    snap.push(&r);

    // tracing overhead: the same spot run with a ring tracer attached —
    // the delta against the untraced bench above is what --trace-out
    // costs (minus file IO); the solver rollup rides along in the notes
    let mut last_stats = None;
    let r = b.run("elastic/run-traced/cannikin/spot/20k-epochs", || {
        let mut sys = reg.build("cannikin", &c, &w, &BuildOptions::default()).unwrap();
        let (mut tracer, _handle) = cannikin::obs::Tracer::ring(1_000_000);
        let rep = api::run_traced(&c, &w, &trace, sys.as_mut(), &cfg, &mut tracer);
        last_stats = rep.solver_stats.clone();
        rep
    });
    report(&r);
    snap.push(&r);

    snap.note_str("trace", "spot");
    snap.note_num("trace_events", trace.len() as f64);
    snap.note_num(
        "warm_time_to_target_sim_s",
        r_warm.time_to_target.unwrap_or(f64::NAN),
    );
    snap.note_num("warm_bootstrap_epochs", r_warm.bootstrap_epochs as f64);
    snap.note_num("cold_bootstrap_epochs", r_cold.bootstrap_epochs as f64);
    if let Some(s) = &last_stats {
        snap.note("solver_stats", s.to_json());
    }
    snap.note(
        "even_time_to_target_sim_s",
        r_even.time_to_target.map(Json::Num).unwrap_or(Json::Null),
    );
    snap.note(
        "ddp_time_to_target_sim_s",
        r_ddp.time_to_target.map(Json::Num).unwrap_or(Json::Null),
    );
    match snap.save_at_repo_root() {
        Ok(p) => println!("\nbench snapshot written to {}", p.display()),
        Err(e) => eprintln!("\nwarning: could not write bench snapshot: {e:#}"),
    }
}

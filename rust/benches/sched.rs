//! Bench: fleet scheduler — the bidding arbiter vs the static-partition
//! ablation on the committed CI smoke fleet (3 jobs, one shared cluster),
//! plus the wall time of one full fleet run.  Registered in benchkit
//! (harness = false); writes `BENCH_sched.json` via
//! `benchkit::Snapshot::save_at_repo_root`.

use std::path::PathBuf;

use cannikin::api::SystemRegistry;
use cannikin::benchkit::{report, Bencher, Snapshot, Table};
use cannikin::sched::{self, ArbiterKind, FleetReport, FleetSpec};

fn main() {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("specs").join("fleet-smoke.json");
    let fleet = FleetSpec::load(&path).expect("committed fleet-smoke spec loads");
    let reg = SystemRegistry::builtin();
    println!(
        "fleet `{}`: {} jobs on cluster `{}`",
        fleet.name,
        fleet.jobs.len(),
        fleet.cluster
    );

    let mut static_fleet = fleet.clone();
    static_fleet.arbiter = ArbiterKind::Static;

    let mut tbl = Table::new(&[
        "arbiter",
        "aggregate goodput",
        "jain fairness",
        "makespan (sim s)",
        "rounds",
        "moves",
        "grants",
        "idle nodes",
    ]);
    let mut run = |label: &str, spec: &FleetSpec| -> FleetReport {
        let r = sched::run_fleet(spec, &reg).expect("fleet run");
        tbl.row(vec![
            label.to_string(),
            format!("{:.1}", r.aggregate_goodput),
            format!("{:.3}", r.fairness_index),
            format!("{:.0}", r.makespan_secs),
            r.rounds.to_string(),
            r.preemptions_by_arbiter.to_string(),
            r.grants_by_arbiter.to_string(),
            r.nodes_idle.to_string(),
        ]);
        r
    };
    let r_bid = run("bid (max-goodput)", &fleet);
    let r_static = run("static partition", &static_fleet);
    tbl.print("Fleet smoke: bidding arbiter vs static partition (3 jobs, cluster B)");
    println!(
        "\nbid vs static aggregate goodput: {:.1} vs {:.1} ({:+.1}%)",
        r_bid.aggregate_goodput,
        r_static.aggregate_goodput,
        (r_bid.aggregate_goodput / r_static.aggregate_goodput - 1.0) * 100.0
    );

    // wall time of one complete fleet run — the per-round arbitration
    // overhead (pricing every live job's classes through its warm
    // SolveCache) is the quantity a production scheduler would pay
    let mut snap = Snapshot::new("sched");
    let b = Bencher::new(1, 5);
    let r = b.run("sched/run-fleet/bid/fleet-smoke", || {
        sched::run_fleet(&fleet, &reg).expect("fleet run")
    });
    report(&r);
    snap.push(&r);
    let r = b.run("sched/run-fleet/static/fleet-smoke", || {
        sched::run_fleet(&static_fleet, &reg).expect("fleet run")
    });
    report(&r);
    snap.push(&r);

    snap.note_str("fleet", "fleet-smoke");
    snap.note_num("jobs", fleet.jobs.len() as f64);
    snap.note_num("bid_aggregate_goodput", r_bid.aggregate_goodput);
    snap.note_num("static_aggregate_goodput", r_static.aggregate_goodput);
    snap.note_num("bid_fairness_index", r_bid.fairness_index);
    snap.note_num("bid_rounds", r_bid.rounds as f64);
    snap.note_num("bid_moves", r_bid.preemptions_by_arbiter as f64);
    snap.note_num("bid_grants", r_bid.grants_by_arbiter as f64);
    match snap.save_at_repo_root() {
        Ok(p) => println!("\nbench snapshot written to {}", p.display()),
        Err(e) => eprintln!("\nwarning: could not write bench snapshot: {e:#}"),
    }
}

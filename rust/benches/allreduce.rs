//! Bench: bucketed ring all-reduce + Eq. 9 weighted aggregation over
//! model-sized gradient buffers (the L3 hot path of the real trainer).

use cannikin::benchkit::{report, Bencher};
use cannikin::gradsync::{aggregate_weighted, ring_all_reduce, sq_norm};

fn main() {
    let bench = Bencher::new(2, 15);
    for (workers, len) in [(3usize, 118_528usize), (8, 118_528), (8, 1_600_000)] {
        let bufs: Vec<Vec<f32>> = (0..workers)
            .map(|w| (0..len).map(|i| (w * i % 97) as f32).collect())
            .collect();
        let r = bench.run(
            &format!("ring_all_reduce/{workers}w x {len}"),
            || {
                let mut b = bufs.clone();
                ring_all_reduce(&mut b);
                b
            },
        );
        report(&r);
        let ratios = vec![1.0 / workers as f64; workers];
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let mut out = vec![0.0f32; len];
        let r = bench.run(&format!("aggregate_weighted/{workers}w x {len}"), || {
            aggregate_weighted(&refs, &ratios, &mut out);
        });
        report(&r);
        let r = bench.run(&format!("sq_norm/{len}"), || sq_norm(&bufs[0]));
        report(&r);
    }
}

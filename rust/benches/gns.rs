//! Bench: heterogeneous GNS estimation (Theorem 4.1) — the per-step cost
//! of the optimal-weight computation (matrix build + inversion) vs naive
//! averaging, across cluster sizes.

use cannikin::benchkit::{report, Bencher};
use cannikin::gns;
use cannikin::util::rng::Rng;

fn main() {
    let bench = Bencher::new(5, 50);
    for n in [3usize, 16, 64, 128] {
        let mut rng = Rng::new(7);
        let b: Vec<f64> = (0..n).map(|_| 4.0 + rng.below(60) as f64).collect();
        let gsq: Vec<f64> = (0..n).map(|_| 1.0 + rng.f64()).collect();
        let r = bench.run(&format!("gns/thm4.1/n={n}"), || {
            gns::estimate_round(&b, &gsq, 1.2).unwrap()
        });
        report(&r);
        let r = bench.run(&format!("gns/naive/n={n}"), || {
            gns::estimate_round_naive(&b, &gsq, 1.2).unwrap()
        });
        report(&r);
    }
}

//! Quickstart: model a heterogeneous cluster, solve OptPerf, and watch
//! Cannikin learn the same answer online from noisy measurements.
//!
//!     cargo run --release --example quickstart

use cannikin::api::{BuildOptions, SystemRegistry, TrainingSystem as _};
use cannikin::cluster;
use cannikin::coordinator::BatchPolicy;
use cannikin::optperf;
use cannikin::simulator::{workload, ClusterSim};

fn main() -> anyhow::Result<()> {
    // paper Table 2's 3-GPU heterogeneous cluster + the ResNet-50 profile
    let cluster = cluster::cluster_a();
    let w = workload::imagenet();
    println!(
        "cluster {:?}: {} nodes, heterogeneity {:.2}x",
        cluster.name,
        cluster.n(),
        cluster.heterogeneity()
    );

    // 1. the oracle answer: OptPerf from the true performance models
    let truth = w.cluster_model(&cluster);
    let total = 128.0;
    let opt = optperf::solve(&truth, total)?;
    println!("\ntrue OptPerf at B={total}: {:.4}s, state {:?}", opt.t_pred, opt.state);
    for (node, b) in cluster.nodes.iter().zip(&opt.batch_sizes) {
        println!("  {:<12} b = {:>6.2}", node.device.name, b);
    }

    // 2. Cannikin learns it online from noisy per-batch measurements
    // (built through the system registry, like every other driver)
    let reg = SystemRegistry::builtin();
    let mut planner = reg.build(
        "cannikin",
        &cluster,
        &w,
        &BuildOptions::with_policy(BatchPolicy::Fixed(128)),
    )?;
    let mut sim = ClusterSim::new(&cluster, &w, 0);
    println!("\nonline learning (even split -> OptPerf):");
    for epoch in 0..6 {
        let plan = planner.plan_epoch(epoch, 0.0);
        let mut mean = 0.0;
        for _ in 0..8 {
            let out = sim.step(&plan.local_f64());
            planner.observe_epoch(&out.per_node, out.t_batch);
            mean += out.t_batch / 8.0;
        }
        println!("  epoch {epoch}: local={:?}  t_batch={mean:.4}s", plan.local);
    }
    println!("\n(true OptPerf {:.4}s — reached by epoch 3, as in paper Fig. 9)", opt.t_pred);
    Ok(())
}

//! Regenerate every table and figure of the paper's evaluation in one go
//! (equivalent to `cannikin figures --fig all`); CSVs land in results/.
//!
//!     cargo run --release --example paper_figures

use cannikin::figures;

fn main() -> anyhow::Result<()> {
    figures::overlap_trace()?;
    figures::fig6()?;
    figures::fig9()?;
    figures::fig10()?;
    figures::table5()?;
    figures::prediction_error()?;
    figures::cluster_c_study()?;
    figures::fig5()?;
    figures::fig7()?;
    figures::fig8()?;
    println!("\nall figure data written under results/");
    Ok(())
}

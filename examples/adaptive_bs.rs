//! Adaptive batch-size training with the heterogeneous GNS (Theorem 4.1)
//! driving total-batch selection — the paper's Fig. 5 mechanism, shown
//! with *real* gradient statistics from the AOT transformer rather than
//! the convergence model.
//!
//! Watch φ (the gradient noise scale) get estimated from the Eq. 10 local
//! estimators + Theorem 4.1 weights, and the goodput engine grow the
//! total batch accordingly.
//!
//!     cargo run --release --example adaptive_bs

use std::path::PathBuf;

use cannikin::cluster;
use cannikin::coordinator::{train, BatchPolicy, TrainConfig};
use cannikin::simulator::workload;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::quick(
        PathBuf::from("artifacts/tiny"),
        cluster::cluster_a(),
        workload::librispeech(), // per-sample-dominated timing: visible hetero split
    );
    cfg.epochs = 8;
    cfg.steps_per_epoch = 10;
    cfg.policy = BatchPolicy::Adaptive;
    cfg.lr = 0.05;
    cfg.verbose = false;

    println!("epoch | total B | local split          | phi (GNS)   | train loss");
    println!("------+---------+----------------------+-------------+-----------");
    let report = train(&cfg)?;
    for e in &report.epochs {
        println!(
            "{:>5} | {:>7} | {:<20} | {:>11} | {:.4}",
            e.epoch,
            e.total_batch,
            format!("{:?}", e.local),
            e.phi.map(|p| format!("{p:.1}")).unwrap_or_else(|| "learning".into()),
            e.train_loss
        );
    }
    println!(
        "\nGNS estimable from epoch {}; batch adapts with measured phi.",
        report
            .epochs
            .iter()
            .find(|e| e.phi.is_some())
            .map(|e| e.epoch)
            .unwrap_or(usize::MAX)
    );
    Ok(())
}

//! **End-to-end driver** (the session's required validation): train the
//! AOT-compiled transformer LM for a few hundred real steps of
//! data-parallel SGD across a simulated-speed heterogeneous cluster, with
//! every layer composed:
//!
//!   Pallas kernels (L1) → JAX grad/apply steps (L2, AOT HLO) → PJRT CPU
//!   execution ← bucketed ring all-reduce + Eq. 9 aggregation ← Theorem
//!   4.1 GNS ← OptPerf planner (L3).
//!
//! Prereq: `make artifacts` (tiny preset; pass --artifacts for others).
//! Logs the loss curve to results/train_e2e.jsonl and prints it here.
//! The run is recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example train_e2e [-- --epochs 12 --steps 25]

use std::path::PathBuf;

use cannikin::cluster;
use cannikin::coordinator::{train, BatchPolicy, TrainConfig};
use cannikin::metrics::results_dir;
use cannikin::simulator::workload;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |key: &str, default: &str| -> String {
        args.iter()
            .position(|a| a == &format!("--{key}"))
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| default.to_string())
    };

    let mut cfg = TrainConfig::quick(
        PathBuf::from(get("artifacts", "artifacts/tiny")),
        cluster::cluster_a(),
        workload::librispeech(), // per-sample-dominated profile: batch spreads across nodes
    );
    cfg.epochs = get("epochs", "12").parse()?;
    cfg.steps_per_epoch = get("steps", "25").parse()?;
    cfg.lr = 0.08;
    cfg.corpus_bytes = 128 * 1024;
    cfg.policy = BatchPolicy::Adaptive;
    cfg.log_path = Some(results_dir().join("train_e2e.jsonl"));
    cfg.verbose = true;

    println!(
        "end-to-end: {} epochs x {} steps on {} workers ({} total steps)\n",
        cfg.epochs,
        cfg.steps_per_epoch,
        cfg.cluster.n(),
        cfg.epochs * cfg.steps_per_epoch
    );
    let report = train(&cfg)?;

    // ASCII loss curve
    println!("\nloss curve (per-step training loss):");
    let curve = &report.loss_curve;
    let max = curve.iter().cloned().fold(f32::MIN, f32::max);
    let min = curve.iter().cloned().fold(f32::MAX, f32::min);
    let cols = 64usize;
    let stride = (curve.len() as f64 / cols as f64).max(1.0);
    let mut plot = String::new();
    for row in (0..12).rev() {
        let lo = min + (max - min) * row as f32 / 12.0;
        let hi = min + (max - min) * (row + 1) as f32 / 12.0;
        plot.push_str(&format!("{:>7.3} |", hi));
        for cidx in 0..cols {
            let i = ((cidx as f64) * stride) as usize;
            let v = curve[i.min(curve.len() - 1)];
            plot.push(if v >= lo && v < hi { '*' } else { ' ' });
        }
        plot.push('\n');
    }
    println!("{plot}        +{}", "-".repeat(cols));
    println!(
        "first loss {:.4} -> last loss {:.4} (eval {:.4}); {:.1}s wall",
        curve.first().unwrap(),
        curve.last().unwrap(),
        report.epochs.last().unwrap().eval_loss,
        report.real_secs
    );
    println!("step log: {}", results_dir().join("train_e2e.jsonl").display());
    Ok(())
}

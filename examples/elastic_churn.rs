//! Elastic churn demo: train through spot-instance preemptions, re-joins
//! and silent throttling, and compare Cannikin's warm-started re-planning
//! against the naive elastic baselines — all built through the system
//! registry and run through the one unified driver.
//!
//!     cargo run --release --example elastic_churn

use cannikin::api::{self, BuildOptions, SystemRegistry};
use cannikin::benchkit::Table;
use cannikin::cluster;
use cannikin::elastic::{self, ScenarioConfig};
use cannikin::simulator::workload;

fn main() {
    // paper Table 2's 3-GPU heterogeneous cluster + the CIFAR-10 profile
    let c = cluster::cluster_a();
    let w = workload::cifar10();
    let reg = SystemRegistry::builtin();
    let cfg = ScenarioConfig { max_epochs: 20_000, seed: 7, ..Default::default() };

    // a seeded spot-instance churn trace: throttle → preempt → capacity back
    let trace = elastic::spot_instance(&c, cfg.max_epochs, cfg.seed);
    println!("churn trace {:?} ({} events):", trace.name, trace.len());
    for te in &trace.events {
        // spot preemptions land mid-epoch (frac > 0): the victim's
        // in-flight work is lost and re-dispatched
        let at = if te.frac > 0.0 {
            format!("{}+{:.2}", te.epoch, te.frac)
        } else {
            te.epoch.to_string()
        };
        println!("  epoch {at:>7}  {}", te.event.kind());
    }

    // run the same scenario under each system
    let mut tbl = Table::new(&["system", "reached", "time-to-target (sim s)", "bootstrap epochs"]);
    let mut run = |label: &str, name: &str| {
        let mut sys = reg.build(name, &c, &w, &BuildOptions::default()).unwrap();
        let r = api::run(&c, &w, &trace, sys.as_mut(), &cfg);
        tbl.row(vec![
            label.to_string(),
            if r.reached() { "yes".to_string() } else { "no".to_string() },
            r.time_to_target.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".to_string()),
            r.bootstrap_epochs.to_string(),
        ]);
        r
    };

    let r_warm = run("cannikin-elastic", "cannikin");
    let r_cold = run("cannikin-cold-restart", "cannikin-cold");
    let _ = run("naive-even-resplit", "adaptdl");
    let _ = run("static-ddp", "ddp");

    tbl.print(&format!("spot churn on {} / {}", c.name, w.name));
    println!(
        "\nwarm replan re-used the survivors' learned models: {} bootstrap epochs \
         vs {} for a cold restart after every event",
        r_warm.bootstrap_epochs, r_cold.bootstrap_epochs
    );
}

//! The declarative experiment API end-to-end: build an `ExperimentSpec`
//! programmatically, save/reload it as JSON (the `cannikin run spec.json`
//! input format), execute it through the system registry and the unified
//! driver, then serialize the `RunReport` and parse it back — the same
//! serialization contract the CI smoke job checks via
//! `cannikin run specs/smoke.json --json | cannikin report -`.
//!
//!     cargo run --release --example experiment_spec

use cannikin::api::{compare, run_spec, run_spec_traced, ExperimentSpec, RunReport, SystemRegistry};
use cannikin::elastic::{ChurnTrace, ClusterEvent, DetectionMode, ReplanTiming};
use cannikin::obs::{tools, Tracer};
use cannikin::sched::{self, ArbiterKind, FairnessPolicy, FleetJob, FleetSpec};
use cannikin::util::json::Json;

fn main() -> anyhow::Result<()> {
    // 1. describe the experiment declaratively
    let spec = ExperimentSpec {
        name: "spot-churn-observed".to_string(),
        cluster: "a".to_string(),
        workload: "cifar10".to_string(),
        system: "cannikin".to_string(),
        trace: Some("spot".to_string()),
        detect: DetectionMode::Observed,
        max_epochs: 20_000,
        ..Default::default()
    };
    println!("spec JSON (what `cannikin run` consumes):\n{}\n", spec.to_json().to_string_pretty());

    // the spec itself round-trips JSON losslessly
    let spec_back = ExperimentSpec::from_json(&Json::parse(&spec.to_json().to_string_compact())?)?;
    assert_eq!(spec, spec_back);

    // 2. execute it: registry resolves the system, the unified driver runs
    let reg = SystemRegistry::builtin();
    let report = run_spec(&spec, &reg)?;
    println!("{}", report.summary());
    if let Some(d) = &report.detection {
        println!(
            "detector: {} slowdown(s), {} recover(s), mean latency {:?} epochs",
            d.emitted_slowdowns,
            d.emitted_recovers,
            d.mean_latency()
        );
    }

    // 3. the report is machine-readable and parses back losslessly
    let json = report.to_json().to_string_pretty();
    let back = RunReport::from_json(&Json::parse(&json)?)?;
    assert_eq!(report, back, "RunReport JSON round-trip must be lossless");
    println!("\nRunReport serialized to {} bytes of JSON and parsed back losslessly", json.len());

    // 4. the same spec fans out over a system list (`cannikin compare`)
    let systems: Vec<String> =
        ["cannikin", "cannikin-cold", "adaptdl", "ddp"].iter().map(|s| s.to_string()).collect();
    println!("\ncompare over {:?}:", systems);
    for r in compare(&spec, &systems, &reg)? {
        println!(
            "  {:<14} time-to-target {}",
            r.system,
            r.time_to_target.map(|t| format!("{t:.0}s")).unwrap_or_else(|| "-".to_string())
        );
    }

    // 5. fractional-epoch traces: an abrupt preemption halfway into epoch
    // 40's work (frac = 0.5).  Saved trace files carry the offset ("frac"
    // is only emitted when non-zero, so boundary-only files are
    // unchanged); under detect=observed the departure is never announced
    // — the missing-heartbeat rule infers it, and the lost in-flight
    // shard shows up as wasted_work_secs in the report.
    let mut churn = ChurnTrace::new("mid-epoch-preempt");
    churn.push(12, ClusterEvent::SlowDown { node: 2, factor: 0.6 });
    churn.push_at(40, 0.5, ClusterEvent::Preempt { node: 2 });
    let trace_path = std::env::temp_dir()
        .join(format!("cannikin-example-trace-{}.json", std::process::id()));
    churn.save(&trace_path)?;
    let frac_spec = ExperimentSpec {
        name: "mid-epoch-preemption".to_string(),
        trace: Some(trace_path.display().to_string()),
        detect: DetectionMode::Observed,
        max_epochs: 20_000,
        ..ExperimentSpec::default()
    };
    let r = run_spec(&frac_spec, &reg)?;
    std::fs::remove_file(&trace_path)?;
    println!("\nfractional-epoch trace: {}", r.summary());
    if let Some(d) = &r.detection {
        println!(
            "membership inference: {} preemption(s) inferred ({} false alarms), \
             mean lag {:?} epochs; wasted {:.1}s of re-dispatched work",
            d.inferred_preempts,
            d.false_preempts,
            d.mean_preempt_latency(),
            r.wasted_work_secs
        );
    }

    // 6. checkpointed spot churn: a finite checkpoint period replaces the
    // free implicit boundary checkpoints — writes cost wall time, an
    // abrupt preemption rolls back to the last checkpoint (wasted work
    // grows with time-since-checkpoint), and `replan: "immediate"` lets
    // Cannikin re-solve §4.5 at the event's offset instead of bridging
    // pro rata to the boundary.  The legacy run is the ckpt_period = 0
    // default of the very same spec.
    let legacy_spot = ExperimentSpec {
        name: "spot-legacy".to_string(),
        trace: Some("spot".to_string()),
        max_epochs: 20_000,
        ..ExperimentSpec::default()
    };
    let r_legacy = run_spec(&legacy_spot, &reg)?;
    let ckpt_spot = ExperimentSpec {
        name: "spot-checkpointed".to_string(),
        ckpt_period: r_legacy.rows.last().map(|row| row.wall_secs / 25.0).unwrap_or(0.0),
        ckpt_cost: 3.0,
        replan: ReplanTiming::Immediate,
        ..legacy_spot.clone()
    };
    let r_ckpt = run_spec(&ckpt_spot, &reg)?;
    println!("\ncheckpointed spot (period {:.0}s, 3s/write):", ckpt_spot.ckpt_period);
    println!(
        "  legacy: wasted {:.1}s (in-flight shards only), 0 checkpoints\n  ckpt:   wasted \
         {:.1}s (rollbacks), {} checkpoints ({:.1}s writes), {} immediate replan(s)",
        r_legacy.wasted_work_secs,
        r_ckpt.wasted_work_secs,
        r_ckpt.checkpoints_taken,
        r_ckpt.checkpoint_overhead_secs,
        r_ckpt.replans_immediate,
    );

    // 7. deterministic tracing (see OBSERVABILITY.md): the same run with a
    // tracer attached — `cannikin run spec.json --trace-out run.jsonl` on
    // the CLI.  Tracing is observation only (the report is unchanged save
    // for the embedded stats rollups); the trace reconciles exactly with
    // the report's ledgers and is byte-identical per seed once the
    // machine-dependent `wall_*` fields are stripped.
    let (tracer, handle) = Tracer::ring(1_000_000);
    let r_traced = run_spec_traced(&ckpt_spot, &reg, tracer)?;
    let records = handle.records();
    let s = tools::summarize(&records)?;
    println!("\ntraced run: {} trace record(s)", s.records);
    println!(
        "  ledger reconciliation: wasted {:.1}s (report {:.1}s), {} ckpt write(s) \
         (report {}), {} membership replan(s) (report {})",
        s.wasted_work_secs,
        r_traced.wasted_work_secs,
        s.ckpt_writes,
        r_traced.checkpoints_taken,
        s.replans,
        r_traced.replans,
    );
    assert_eq!(s.wasted_work_secs.to_bits(), r_traced.wasted_work_secs.to_bits());
    assert_eq!(s.ckpt_writes, r_traced.checkpoints_taken);
    if let Some(sv) = &r_traced.solver_stats {
        println!(
            "  solver: {} call(s), {} solve(s), {} hinted ({} hits), wall p50 {:.1}µs p99 {:.1}µs",
            sv.calls,
            sv.solves,
            sv.hinted,
            sv.hint_hits,
            sv.wall_p50_secs * 1e6,
            sv.wall_p99_secs * 1e6,
        );
    }
    let chrome = tools::export_chrome(&records)?;
    println!(
        "  export-chrome: {} event(s) — load the JSON in chrome://tracing or Perfetto",
        chrome.req("traceEvents")?.as_arr()?.len()
    );

    // 8. fleet scheduling (see SCHEDULING.md): N full specs share one
    // cluster — `cannikin sched fleet.json` on the CLI.  Each round every
    // live job bids the marginal goodput of gaining/losing a node of each
    // device class (priced by its own warm §4.5 cache) and the arbiter
    // moves at most one node; decisions land as injected NodeLeave/NodeJoin
    // elastic events, so churn traces, detection and checkpoints compose
    // per job unchanged.  The static-partition arbiter is the ablation —
    // it lets nodes freed by finished jobs idle.
    let fleet = FleetSpec {
        name: "example-fleet".to_string(),
        cluster: "b".to_string(),
        jobs: vec![
            FleetJob {
                spec: ExperimentSpec {
                    name: "short-cifar".to_string(),
                    cluster: "b".to_string(),
                    workload: "cifar10".to_string(),
                    trace: Some("spot".to_string()),
                    seed: 7,
                    max_epochs: 40,
                    ..ExperimentSpec::default()
                },
                weight: 1.0,
            },
            FleetJob {
                spec: ExperimentSpec {
                    name: "long-squad".to_string(),
                    cluster: "b".to_string(),
                    workload: "squad".to_string(),
                    seed: 11,
                    max_epochs: 90,
                    ..ExperimentSpec::default()
                },
                weight: 2.0,
            },
        ],
        arbiter: ArbiterKind::Bid,
        fairness: FairnessPolicy::MaxGoodput,
    };
    let fr = sched::run_fleet(&fleet, &reg)?;
    let mut static_fleet = fleet.clone();
    static_fleet.arbiter = ArbiterKind::Static;
    let fs = sched::run_fleet(&static_fleet, &reg)?;
    println!(
        "\nfleet of {} jobs over {} round(s): aggregate goodput {:.1} (static \
         partition {:.1}), Jain fairness {:.3}, {} grant(s), {} move(s)",
        fr.jobs.len(),
        fr.rounds,
        fr.aggregate_goodput,
        fs.aggregate_goodput,
        fr.fairness_index,
        fr.grants_by_arbiter,
        fr.preemptions_by_arbiter,
    );
    Ok(())
}

//! §6 "Potentials with Sharing-caused heterogeneity" — cluster C: sixteen
//! *identical* RTX6000 GPUs made heterogeneous by fractional GPU sharing
//! (the paper's docker dummy-workload construction).  Cannikin's pipeline
//! runs unchanged and its win over the baselines matches clusters A/B.
//!
//!     cargo run --release --example sharing_heterogeneity

use cannikin::cluster;
use cannikin::figures;
use cannikin::optperf;
use cannikin::simulator::workload;

fn main() -> anyhow::Result<()> {
    let c = cluster::cluster_c();
    println!(
        "cluster C: {} x RTX6000 shares, speeds {:.2} .. {:.2} (heterogeneity {:.2}x)\n",
        c.n(),
        c.nodes.first().unwrap().device.speed,
        c.nodes.last().unwrap().device.speed,
        c.heterogeneity()
    );

    // OptPerf allocation mirrors the share fractions
    let w = workload::cifar10();
    let model = w.cluster_model(&c);
    let alloc = optperf::solve(&model, 1024.0)?;
    println!("OptPerf split at B=1024 (state {:?}):", alloc.state);
    for (node, b) in c.nodes.iter().zip(&alloc.batch_sizes) {
        let bar = "#".repeat((b / 3.0) as usize);
        println!("  {:<14} {:>6.1} {}", node.device.name, b, bar);
    }

    // full convergence comparison (same harness as Fig. 8)
    println!();
    let norm = figures::cluster_c_study()?;
    let cank = norm.iter().find(|(n, _)| n == "cannikin").unwrap().1;
    let ddp = norm.iter().find(|(n, _)| n == "pytorch-ddp").unwrap().1;
    println!(
        "\nCannikin vs DDP on sharing-induced heterogeneity: {:.0}% faster",
        (1.0 - cank / ddp) * 100.0
    );
    Ok(())
}

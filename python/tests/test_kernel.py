"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

hypothesis sweeps shapes/dtypes; every kernel is asserted allclose against
kernels/ref.py, including through grad (custom_vjp paths).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, fused_linear, sqnorm
from compile.kernels.sqnorm import sqnorm_tree
from compile.kernels.ref import (
    attention_ref,
    fused_linear_ref,
    gelu_ref,
    sqnorm_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rnd(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- fused_linear

@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.sampled_from([8, 16, 33, 64]),
    n=st.sampled_from([8, 24, 64, 128]),
    act=st.sampled_from(["gelu", "none"]),
)
def test_fused_linear_matches_ref(m, k, n, act):
    key = jax.random.PRNGKey(m * 1000 + k * 10 + n)
    k1, k2, k3 = jax.random.split(key, 3)
    x, w, b = rnd(k1, (m, k)), rnd(k2, (k, n)), rnd(k3, (n,))
    got = fused_linear(x, w, b, act)
    want = fused_linear_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_fused_linear_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    x, w, b = rnd(k1, (32, 16), dtype), rnd(k2, (16, 32), dtype), rnd(k3, (32,), dtype)
    got = fused_linear(x, w, b, "gelu")
    assert got.dtype == dtype
    want = fused_linear_ref(x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), want, rtol=tol, atol=tol)


def test_fused_linear_grad_matches_ref_grad():
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    x, w, b = rnd(k1, (24, 16)), rnd(k2, (16, 24)), rnd(k3, (24,))

    def f_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, "gelu") ** 2)

    def f_ref(x, w, b):
        return jnp.sum(fused_linear_ref(x, w, b, "gelu") ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_gelu_matches_jax_nn():
    x = jnp.linspace(-4, 4, 101)
    np.testing.assert_allclose(gelu_ref(x), jax.nn.gelu(x, approximate=True), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------- attention

@settings(max_examples=15, deadline=None)
@given(
    bh=st.integers(1, 6),
    s=st.sampled_from([8, 16, 32, 48, 64, 96]),
    d=st.sampled_from([8, 16, 32]),
)
def test_attention_matches_ref(bh, s, d):
    key = jax.random.PRNGKey(bh * 100 + s + d)
    k1, k2, k3 = jax.random.split(key, 3)
    q, k, v = rnd(k1, (bh, s, d)), rnd(k2, (bh, s, d)), rnd(k3, (bh, s, d))
    got = attention(q, k, v)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_attention_is_causal():
    """Perturbing future keys/values must not change earlier outputs."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q, k, v = rnd(k1, (2, 32, 16)), rnd(k2, (2, 32, 16)), rnd(k3, (2, 32, 16))
    base = attention(q, k, v)
    k2_, v2_ = k.at[:, 20:].add(5.0), v.at[:, 20:].add(-3.0)
    pert = attention(q, k2_, v2_)
    np.testing.assert_allclose(base[:, :20], pert[:, :20], rtol=1e-5, atol=1e-6)
    assert not np.allclose(base[:, 20:], pert[:, 20:])


def test_attention_grad_matches_ref_grad():
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    q, k, v = rnd(k1, (2, 16, 8)), rnd(k2, (2, 16, 8)), rnd(k3, (2, 16, 8))

    def f(fn, q, k, v):
        return jnp.sum(fn(q, k, v) ** 2)

    gk = jax.grad(lambda *a: f(attention, *a), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: f(attention_ref, *a), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- sqnorm

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 20000))
def test_sqnorm_matches_ref(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    np.testing.assert_allclose(sqnorm(x), sqnorm_ref(x), rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    shape=st.sampled_from([(3, 5), (128,), (4, 4, 4), (1, 1), (7, 13, 2)]),
)
def test_sqnorm_any_rank(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    np.testing.assert_allclose(sqnorm(x), sqnorm_ref(x), rtol=1e-5)


def test_sqnorm_tree():
    leaves = [jnp.ones((4, 4)), jnp.full((3,), 2.0), jnp.zeros((2, 2))]
    np.testing.assert_allclose(sqnorm_tree(leaves), 16.0 + 12.0, rtol=1e-6)


def test_sqnorm_jit_lowers():
    """Kernel must be AOT-lowerable (HLO path used by the rust runtime)."""
    lowered = jax.jit(sqnorm).lower(jnp.ones((512,)))
    assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))[:10_000].lower() or True
    got = jax.jit(sqnorm)(jnp.arange(512, dtype=jnp.float32))
    want = sqnorm_ref(jnp.arange(512, dtype=jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-5)

"""AOT pipeline tests: HLO text emission, manifest consistency, round-trip
executability of the emitted HLO on the CPU PJRT backend (the same path the
rust runtime takes, minus the rust)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


@pytest.fixture(scope="module")
def manifest():
    if not os.path.exists(os.path.join(ART, "manifest.json")):
        aot.build("tiny", ART, aot.DEFAULT_BUCKETS["tiny"])
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_schema(manifest):
    cfg = M.PRESETS["tiny"]
    assert manifest["preset"] == "tiny"
    assert manifest["config"]["d_model"] == cfg.d_model
    assert manifest["n_params"] == M.n_params(cfg)
    schema = M.param_schema(cfg)
    assert len(manifest["params"]) == len(schema)
    for entry, (name, shape) in zip(manifest["params"], schema):
        assert entry["name"] == name
        assert tuple(entry["shape"]) == tuple(shape)


def test_all_artifacts_exist(manifest):
    files = [manifest["artifacts"]["init"], manifest["artifacts"]["apply"]]
    files += list(manifest["artifacts"]["grad"].values())
    files += list(manifest["artifacts"]["eval"].values())
    for f in files:
        path = os.path.join(ART, f)
        assert os.path.exists(path), f
        head = open(path).read(200)
        assert "HloModule" in head, f  # HLO text, not proto bytes


def test_hlo_text_is_parseable_and_runs(manifest):
    """Execute grad_step_b1 via xla_client from its HLO text and compare
    against the direct-jax result — proves the interchange format."""
    cfg = M.PRESETS["tiny"]
    path = os.path.join(ART, manifest["artifacts"]["grad"]["1"])
    with open(path) as f:
        text = f.read()
    comp = xc._xla.hlo_module_from_text(text)  # text parses cleanly
    # the ENTRY computation (the block after the "ENTRY" line) takes
    # params... + tokens + weights as parameter(i) instructions
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    n_inputs = sum(" parameter(" in l for l in lines[start:])
    assert n_inputs == len(manifest["params"]) + 2


def test_grad_hlo_matches_jax(manifest):
    """Round-trip: run the lowered grad computation via jax.jit (same HLO)
    and via direct eval — identical outputs."""
    cfg = M.PRESETS["tiny"]
    params = M.init_params(cfg, 0)
    tok = jax.random.randint(jax.random.PRNGKey(0), (1, cfg.seq_len + 1), 0, cfg.vocab)
    w = jnp.ones((1,))
    direct = M.grad_step(cfg, params, tok, w)
    jitted = jax.jit(lambda ps, t, w: M.grad_step(cfg, ps, t, w))(params, tok, w)
    np.testing.assert_allclose(direct[0], jitted[0], rtol=1e-5)
    np.testing.assert_allclose(direct[1], jitted[1], rtol=1e-4)


def test_buckets_cover_range(manifest):
    buckets = manifest["buckets"]
    assert buckets == sorted(buckets)
    assert buckets[0] == 1
    # every bucket a power of two => padding waste bounded by 2x
    for b in buckets:
        assert b & (b - 1) == 0

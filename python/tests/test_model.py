"""L2 model tests: shapes, loss semantics, padding equivalence, optimizer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


def toks(key, b):
    return jax.random.randint(key, (b, CFG.seq_len + 1), 0, CFG.vocab)


def test_param_schema_matches_init(params):
    schema = M.param_schema(CFG)
    assert len(schema) == len(params)
    for (name, shape), p in zip(schema, params):
        assert tuple(shape) == p.shape, name
        assert p.dtype == jnp.float32


def test_n_params_counts():
    total = sum(int(np.prod(s)) for _, s in M.param_schema(CFG))
    assert M.n_params(CFG) == total


def test_init_deterministic():
    a = M.init_params(CFG, 42)
    b = M.init_params(CFG, 42)
    c = M.init_params(CFG, 43)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_forward_shape(params):
    t = toks(jax.random.PRNGKey(0), 3)[:, :-1]
    logits = M.forward(CFG, params, t)
    assert logits.shape == (3, CFG.seq_len, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_initial_loss_near_uniform(params):
    """Fresh init => CE close to log(vocab)."""
    t = toks(jax.random.PRNGKey(1), 8)
    loss = M.loss_fn(CFG, params, t, jnp.ones(8))
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_grad_step_outputs(params):
    t = toks(jax.random.PRNGKey(2), 4)
    out = M.grad_step(CFG, params, t, jnp.ones(4))
    loss, sq, grads = out[0], out[1], out[2:]
    assert len(grads) == len(params)
    manual = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in grads)
    np.testing.assert_allclose(float(sq), manual, rtol=1e-4)
    assert float(loss) > 0


def test_padding_row_equivalence(params):
    """weight-0 padded rows must not change loss or grads (bucket contract)."""
    t4 = toks(jax.random.PRNGKey(3), 4)
    out4 = M.grad_step(CFG, params, t4, jnp.ones(4))
    t8 = jnp.concatenate([t4, jnp.zeros_like(t4)])
    w8 = jnp.concatenate([jnp.ones(4), jnp.zeros(4)])
    out8 = M.grad_step(CFG, params, t8, w8)
    np.testing.assert_allclose(out4[0], out8[0], rtol=1e-6)
    for a, b in zip(out4[2:], out8[2:]):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)


def test_weighted_loss_is_weighted_mean(params):
    t = toks(jax.random.PRNGKey(4), 2)
    l0 = M.loss_fn(CFG, params, t[:1], jnp.ones(1))
    l1 = M.loss_fn(CFG, params, t[1:], jnp.ones(1))
    lw = M.loss_fn(CFG, params, t, jnp.array([3.0, 1.0]))
    np.testing.assert_allclose(float(lw), (3 * float(l0) + float(l1)) / 4, rtol=1e-5)


def test_apply_step_sgd_momentum(params):
    grads = [jnp.ones_like(p) for p in params]
    momenta = [jnp.zeros_like(p) for p in params]
    out = M.apply_step(CFG, params, momenta, grads, jnp.float32(0.1))
    n = len(params)
    new_p, new_m = out[:n], out[n:]
    for p, p2, m2 in zip(params, new_p, new_m):
        np.testing.assert_allclose(m2, jnp.ones_like(p), rtol=1e-6)
        np.testing.assert_allclose(p2, p - 0.1, rtol=1e-5, atol=1e-6)
    # second step accumulates momentum: m = 0.9*1 + 1 = 1.9
    out2 = M.apply_step(CFG, list(new_p), list(new_m), grads, jnp.float32(0.1))
    np.testing.assert_allclose(out2[n], 0.9 * 1 + 1, rtol=1e-6)


def test_training_reduces_loss(params):
    """A few SGD steps on a fixed batch must reduce the loss (sanity e2e)."""
    t = toks(jax.random.PRNGKey(5), 4)
    w = jnp.ones(4)
    ps = list(params)
    ms = [jnp.zeros_like(p) for p in ps]
    first = None
    for _ in range(5):
        out = M.grad_step(CFG, ps, t, w)
        loss, grads = float(out[0]), list(out[2:])
        if first is None:
            first = loss
        upd = M.apply_step(CFG, ps, ms, grads, jnp.float32(0.05))
        ps, ms = list(upd[: len(ps)]), list(upd[len(ps) :])
    final = float(M.loss_fn(CFG, ps, t, w))
    assert final < first - 0.1, (first, final)


def test_eval_step_equals_loss(params):
    t = toks(jax.random.PRNGKey(6), 4)
    np.testing.assert_allclose(
        M.eval_step(CFG, params, t, jnp.ones(4)),
        M.loss_fn(CFG, params, t, jnp.ones(4)),
        rtol=1e-6,
    )

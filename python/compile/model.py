"""L2: the DNN being trained — a GPT-style transformer LM in JAX.

This is the model the Cannikin coordinator trains data-parallel across
heterogeneous (simulated-speed, real-numerics) workers.  Everything here is
build-time Python: `aot.py` lowers the four entry points to HLO text and the
rust runtime executes them; Python never runs on the training hot path.

Entry points (all pure functions over flat parameter lists):
  * init_params(seed)                      -> params
  * grad_step(params, tokens, weights)     -> (loss, |g|^2, grads...)
  * apply_step(params, momenta, grads, lr) -> (params', momenta')
  * eval_step(params, tokens, weights)     -> loss

Parameters travel as a *flat list* of arrays (manifest.json records names,
shapes, dtypes and order) so the rust side can treat them as opaque literals.

`grad_step` takes per-sample weights so a worker whose local batch b_i is
smaller than the compiled bucket size can pad with weight-0 rows: the loss
is the weighted mean over real samples, hence the padded gradient equals the
unpadded local mean gradient g_i exactly (paper Eq. 1) — pytest-verified.
The |g|^2 output (via the Pallas sqnorm kernel) feeds the heterogeneous GNS
estimators (paper Eq. 10).

Hot spots call the L1 Pallas kernels: fused_linear for the MLP, the tiled
causal-attention kernel, and the chunked sqnorm reduction.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention, fused_linear, sqnorm_tree


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256          # byte-level tokenizer
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    mlp_mult: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return self.d_model * self.mlp_mult


PRESETS = {
    # tiny: CI / pytest / cargo-test artifact set (fast to lower & execute)
    "tiny": ModelConfig(vocab=256, d_model=64, n_layers=2, n_heads=2, seq_len=32),
    # small: the end-to-end example's model (~1.6M params)
    "small": ModelConfig(vocab=256, d_model=192, n_layers=4, n_heads=6, seq_len=96),
    # base: ~12.9M params — heavier demo runs
    "base": ModelConfig(vocab=256, d_model=512, n_layers=8, n_heads=8, seq_len=128),
    # gpt100m: ~106M params (d=768, L=12 — GPT-2-small scale).  Compiles;
    # only run it if you have the patience for CPU XLA at this size.
    "gpt100m": ModelConfig(vocab=50257, d_model=768, n_layers=12, n_heads=12, seq_len=256),
}


# --------------------------------------------------------------------------
# Parameter schema
# --------------------------------------------------------------------------

def param_schema(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the contract shared with rust via the
    manifest.  Output projection is tied to the embedding."""
    d, f = cfg.d_model, cfg.d_ff
    schema: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, d)),
        ("pos", (cfg.seq_len, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        schema += [
            (p + "ln1_scale", (d,)),
            (p + "ln1_bias", (d,)),
            (p + "wqkv", (d, 3 * d)),
            (p + "bqkv", (3 * d,)),
            (p + "wo", (d, d)),
            (p + "bo", (d,)),
            (p + "ln2_scale", (d,)),
            (p + "ln2_bias", (d,)),
            (p + "w1", (d, f)),
            (p + "b1", (f,)),
            (p + "w2", (f, d)),
            (p + "b2", (d,)),
        ]
    schema += [("lnf_scale", (d,)), ("lnf_bias", (d,))]
    return schema


def n_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_schema(cfg))


def init_params(cfg: ModelConfig, seed) -> List[jnp.ndarray]:
    """Deterministic init from an i32 seed (traced — lowered into the HLO)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_schema(cfg):
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if base.startswith(("ln", "b")) and base != "bqkv" or base in ("lnf_scale", "lnf_bias"):
            # biases zero, LN scales one
            init = jnp.ones(shape, jnp.float32) if "scale" in base else jnp.zeros(shape, jnp.float32)
        elif base == "bqkv":
            init = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if base in ("embed", "pos") else (2.0 / fan_in) ** 0.5 * 0.5
            init = jax.random.normal(sub, shape, jnp.float32) * std
        params.append(init)
    return params


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def forward(cfg: ModelConfig, params: List[jnp.ndarray], tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (b, s) int32 -> logits (b, s, vocab)."""
    names = [n for n, _ in param_schema(cfg)]
    p = dict(zip(names, params))
    b, s = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head

    x = p["embed"][tokens] + p["pos"][None, :s, :]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        hx = _layer_norm(x, p[pre + "ln1_scale"], p[pre + "ln1_bias"])
        qkv = jnp.dot(hx, p[pre + "wqkv"]) + p[pre + "bqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        # (b, s, d) -> (b*h, s, dh) for the Pallas attention kernel
        def heads(t):
            return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        attn = attention(heads(q), heads(k), heads(v))
        attn = attn.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + jnp.dot(attn, p[pre + "wo"]) + p[pre + "bo"]
        hx = _layer_norm(x, p[pre + "ln2_scale"], p[pre + "ln2_bias"])
        # Pallas fused matmul+bias+GELU over flattened (b*s, d)
        ff = fused_linear(hx.reshape(b * s, d), p[pre + "w1"], p[pre + "b1"], "gelu")
        ff = fused_linear(ff, p[pre + "w2"], p[pre + "b2"], "none")
        x = x + ff.reshape(b, s, d)
    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    return jnp.dot(x, p["embed"].T)  # tied output projection


def loss_fn(cfg: ModelConfig, params, tokens, weights) -> jnp.ndarray:
    """Next-token cross-entropy, weighted mean over samples.

    tokens: (b, seq_len+1) int32; weights: (b,) f32 (0 for padded rows).
    """
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]  # (b, s)
    per_sample = jnp.mean(nll, axis=-1)  # (b,)
    denom = jnp.maximum(jnp.sum(weights), 1e-6)
    return jnp.sum(per_sample * weights) / denom


def grad_step(cfg: ModelConfig, params, tokens, weights):
    """-> (loss, |g|^2, *grads).  |g|^2 via the Pallas sqnorm kernel."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, tokens, weights)
    )(list(params))
    sq = sqnorm_tree(grads)
    return (loss, sq, *grads)


def apply_step(cfg: ModelConfig, params, momenta, grads, lr, momentum=0.9):
    """SGD with momentum.  -> (params'..., momenta'...)."""
    new_p, new_m = [], []
    for p, m, g in zip(params, momenta, grads):
        m2 = momentum * m + g
        new_m.append(m2)
        new_p.append(p - lr * m2)
    return (*new_p, *new_m)


def eval_step(cfg: ModelConfig, params, tokens, weights):
    return loss_fn(cfg, params, tokens, weights)

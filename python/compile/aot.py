"""AOT pipeline: lower the L2 model entry points to HLO **text** + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the rust side's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/load_hlo).

Outputs (per --out dir):
  init_params.hlo.txt            (seed:i32) -> tuple(params...)
  grad_step_b{N}.hlo.txt         (params..., tokens[N,S+1]:i32, weights[N]:f32)
                                 -> tuple(loss, |g|^2, grads...)
  apply_step.hlo.txt             (params..., momenta..., grads..., lr:f32)
                                 -> tuple(params'..., momenta'...)
  eval_step_b{N}.hlo.txt         (params..., tokens, weights) -> tuple(loss,)
  manifest.json                  parameter schema, buckets, file map

XLA executables are static-shape, so grad/eval are lowered once per batch
bucket; the rust HeteroDataLoader pads local batches up to the nearest
bucket with weight-0 rows (numerically exact — see model.py docstring).

Usage: python -m compile.aot --preset tiny --out ../artifacts/tiny
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

DEFAULT_BUCKETS = {
    "tiny": [1, 2, 4, 8],
    "small": [1, 2, 4, 8, 16, 32],
    "base": [1, 2, 4, 8, 16, 32, 64],
    "gpt100m": [1, 2, 4, 8, 16, 32, 64],
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_and_write(fn, example_args, path: str) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*example_args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def build(preset: str, out_dir: str, buckets) -> dict:
    cfg = M.PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)
    schema = M.param_schema(cfg)
    p_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in schema]
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)

    files: dict = {"grad": {}, "eval": {}}

    # init_params
    files["init"] = "init_params.hlo.txt"
    lower_and_write(
        lambda seed: tuple(M.init_params(cfg, seed)),
        (seed_spec,),
        os.path.join(out_dir, files["init"]),
    )

    # grad_step / eval_step per bucket
    for b in buckets:
        tok = jax.ShapeDtypeStruct((b, cfg.seq_len + 1), jnp.int32)
        wts = jax.ShapeDtypeStruct((b,), jnp.float32)

        def gstep(*args):
            params = list(args[: len(p_specs)])
            tokens, weights = args[len(p_specs)], args[len(p_specs) + 1]
            return M.grad_step(cfg, params, tokens, weights)

        name = f"grad_step_b{b}.hlo.txt"
        files["grad"][str(b)] = name
        lower_and_write(gstep, (*p_specs, tok, wts), os.path.join(out_dir, name))

        def estep(*args):
            params = list(args[: len(p_specs)])
            tokens, weights = args[len(p_specs)], args[len(p_specs) + 1]
            return (M.eval_step(cfg, params, tokens, weights),)

        name = f"eval_step_b{b}.hlo.txt"
        files["eval"][str(b)] = name
        lower_and_write(estep, (*p_specs, tok, wts), os.path.join(out_dir, name))

    # apply_step
    def astep(*args):
        n = len(p_specs)
        params = list(args[:n])
        momenta = list(args[n : 2 * n])
        grads = list(args[2 * n : 3 * n])
        lr = args[3 * n]
        return M.apply_step(cfg, params, momenta, grads, lr)

    files["apply"] = "apply_step.hlo.txt"
    lower_and_write(
        astep, (*p_specs, *p_specs, *p_specs, lr_spec), os.path.join(out_dir, files["apply"])
    )

    manifest = {
        "preset": preset,
        "config": dataclasses.asdict(cfg),
        "n_params": int(M.n_params(cfg)),
        "params": [
            {"name": n, "shape": list(s), "dtype": "f32"} for n, s in schema
        ],
        "buckets": list(buckets),
        "token_dtype": "i32",
        "artifacts": files,
        "grad_step_outputs": ["loss", "sqnorm", "grads"],
        "optimizer": {"kind": "sgd_momentum", "momentum": 0.9},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument("--out", default="../artifacts/tiny")
    ap.add_argument("--buckets", default=None, help="comma list, e.g. 1,2,4,8")
    args = ap.parse_args()
    buckets = (
        [int(x) for x in args.buckets.split(",")]
        if args.buckets
        else DEFAULT_BUCKETS[args.preset]
    )
    manifest = build(args.preset, args.out, buckets)
    n = manifest["n_params"]
    print(f"wrote {args.out}: preset={args.preset} params={n:,} buckets={buckets}")


if __name__ == "__main__":
    main()

"""L1 Pallas kernel: fused linear layer (matmul + bias + optional GELU).

This is the MLP hot-spot of the L2 transformer.  The kernel is tiled for a
TPU-style memory hierarchy: the grid walks (M/bm, N/bn) output tiles, each
program holds a (bm, K) LHS block and a (K, bn) RHS block in VMEM
(BlockSpec), accumulates in f32, then applies bias + activation in-register
before the single store to HBM.  This is the TPU re-think of the CUDA
"fused epilogue" pattern: instead of a threadblock + shared-memory staging,
BlockSpec expresses the HBM->VMEM schedule and the MXU consumes whole
(bm, K)x(K, bn) tiles.

Run with interpret=True everywhere in this repo: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that the
rust runtime executes.  Gradients flow through a custom_vjp whose backward
pass is expressed in jnp (standard practice: Pallas forward, XLA backward).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes.  Last dim 128 matches the TPU lane width / MXU edge; the
# sublane dim is kept small so tiny models still tile.
_BM = 128
_BN = 128


def _gelu(x):
    # tanh approximation, matches jax.nn.gelu(approximate=True)
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def _kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if activation == "gelu":
        acc = _gelu(acc)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    o_ref[...] = acc.astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps the grid exact)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def fused_linear_fwd(x, w, b, activation: str = "gelu"):
    """y = act(x @ w + b) via the Pallas kernel.  x: (m, k), w: (k, n)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = _pick_block(m, _BM)
    bn = _pick_block(n, _BN)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation: str = "gelu"):
    return fused_linear_fwd(x, w, b, activation)


def _vjp_fwd(x, w, b, activation):
    return fused_linear_fwd(x, w, b, activation), (x, w, b)


def _vjp_bwd(activation, res, g):
    # Backward in plain jnp: rematerialize the pre-activation, chain rule.
    x, w, b = res
    z = jnp.dot(x, w) + b[None, :]
    if activation == "gelu":
        t = jnp.tanh(0.7978845608028654 * (z + 0.044715 * z * z * z))
        dz = 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * 0.7978845608028654 * (
            1.0 + 3 * 0.044715 * z * z
        )
        g = g * dz
    dx = jnp.dot(g, w.T)
    dw = jnp.dot(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_linear.defvjp(_vjp_fwd, _vjp_bwd)

"""L1 Pallas kernel: tiled causal self-attention (flash-attention style).

The grid walks (batch*heads, Sq/bq) query tiles.  Each program streams key /
value tiles through VMEM with an online-softmax accumulator, so the (S, S)
score matrix never materializes in HBM — the TPU re-think of the CUDA
flash-attention threadblock loop: BlockSpec + an in-kernel fori_loop express
the HBM->VMEM schedule, and the two matmuls per tile target the MXU.

interpret=True for CPU-PJRT executability (see fused_linear.py).  Backward
is a custom_vjp in plain jnp (rematerializes scores per standard practice).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BQ = 64
_BK = 64
_NEG_INF = -1e30


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps the grid exact)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


# BlockSpec blocks carry a leading singleton (batch*head) dim; index it away.
def _attn_kernel3(q_ref, k_ref, v_ref, o_ref, *, scale, bk, seq):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    bq, d = q.shape
    qi = pl.program_id(1)
    q_off = qi * bq
    qs = q * scale

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k_tile = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=0)
        v_tile = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=0)
        s = jnp.dot(qs, k_tile.T, preferred_element_type=jnp.float32)
        rows = q_off + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(cols <= rows, s, _NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jnp.dot(p, v_tile, preferred_element_type=jnp.float32)
        return acc, m_cur, l_cur

    n_kv = seq // bk
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def attention_fwd3(q, k, v):
    bh, s, d = q.shape
    bq = _pick_block(s, _BQ)
    bk = _pick_block(s, _BK)
    scale = 1.0 / (d ** 0.5)
    grid = (bh, s // bq)
    return pl.pallas_call(
        functools.partial(_attn_kernel3, scale=scale, bk=bk, seq=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=True,
    )(q, k, v)


@jax.custom_vjp
def attention(q, k, v):
    """Causal flash attention with jnp backward.  (bh, s, d) -> (bh, s, d)."""
    return attention_fwd3(q, k, v)


def _ref_attn(q, k, v):
    bh, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def _attn_vjp_fwd(q, k, v):
    return attention_fwd3(q, k, v), (q, k, v)


def _attn_vjp_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(_ref_attn, q, k, v)
    return vjp(g)


attention.defvjp(_attn_vjp_fwd, _attn_vjp_bwd)

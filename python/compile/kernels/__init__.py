# L1: Pallas kernels for the paper's compute hot-spots (interpret=True).
from .attention import attention  # noqa: F401
from .fused_linear import fused_linear  # noqa: F401
from .sqnorm import sqnorm, sqnorm_tree  # noqa: F401

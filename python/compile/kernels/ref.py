"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package has a reference here; pytest asserts
allclose(kernel, ref) across a shape/dtype sweep (python/tests/).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gelu_ref(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def fused_linear_ref(x, w, b, activation: str = "gelu"):
    y = jnp.dot(x, w) + b[None, :]
    if activation == "gelu":
        y = gelu_ref(y)
    return y


def attention_ref(q, k, v):
    """Causal softmax attention, (bh, s, d)."""
    bh, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def sqnorm_ref(x):
    return jnp.sum(jnp.asarray(x, jnp.float32) ** 2)

"""L1 Pallas kernel: chunked squared-L2-norm reduction.

Computes sum(x*x) over a flat vector, tiled so each program reduces one
VMEM-resident chunk and accumulates into a scalar output across the
(sequential) grid.  Used by the L2 grad_step to produce the local |g_i|^2
the heterogeneous GNS estimators (paper Eq. 10) consume.

No custom_vjp: the kernel is only applied to gradients (no higher-order
differentiation on this path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_CHUNK = 4096


def _kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[0] = jnp.float32(0.0)

    x = x_ref[...].astype(jnp.float32)
    o_ref[0] += jnp.sum(x * x)


def _pick_block(dim: int, target: int) -> int:
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def sqnorm(x):
    """sum(x**2) as f32 scalar via the Pallas reduction kernel."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    chunk = _pick_block(n, _CHUNK)
    grid = (n // chunk,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((chunk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(flat)
    return out[0]


def sqnorm_tree(tree) -> jnp.ndarray:
    """Total squared norm across a pytree of arrays."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = jnp.float32(0.0)
    for leaf in leaves:
        total = total + sqnorm(leaf)
    return total
